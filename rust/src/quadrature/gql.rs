//! Gauss Quadrature Lanczos (paper Alg. 5): iteratively tightening lower
//! and upper bounds on `u^T A^{-1} u`.
//!
//! Per iteration the state advances one Lanczos step (one matvec — the hot
//! path, O(nnz)) and updates the `[J_i^{-1}]_{1,1}` Sherman–Morrison
//! recurrences for the Gauss estimate plus the three modified-Jacobi
//! corrections:
//!
//! * Gauss `g`           → lower bound,
//! * right Gauss-Radau `g_rr` (prescribed eigenvalue λ_max) → lower bound,
//! * left Gauss-Radau  `g_lr` (prescribed eigenvalue λ_min) → upper bound,
//! * Gauss-Lobatto     `g_lo` (both prescribed)             → upper bound.
//!
//! Monotonicity/ordering (Thm. 4/6, Corr. 7) and the linear rates
//! (Thm. 3/5/8) are asserted as property tests below and in
//! `rust/tests/prop_quadrature.rs`.
//!
//! No allocation happens inside [`Gql::step`]; all buffers are preallocated
//! in [`Gql::new`] (perf deliverable — see EXPERIMENTS.md §Perf).
//!
//! The recurrence arithmetic itself lives in [`super::recurrence`] — this
//! type is a thin driver (one matvec + one [`LaneCore::step_column`] on a
//! width-1 panel) over the same core the block engine's lanes use, which
//! is what makes scalar/block bit-identity structural.

use super::recurrence::LaneCore;
use crate::sparse::SymOp;

/// Reorthogonalization policy for the Lanczos basis (§5.4 "Instability").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reorth {
    /// No reorthogonalization: the paper's default, O(nnz) per iteration.
    None,
    /// Full two-pass Gram–Schmidt against the stored basis: O(n·i) extra
    /// per iteration; used when bound validity at high iteration counts
    /// matters more than speed (ablated in `bench_ablation`).
    Full,
}

/// Options for a GQL run.
#[derive(Clone, Copy, Debug)]
pub struct GqlOptions {
    /// Estimate strictly below the smallest eigenvalue (λ_min in the
    /// paper; must be > 0 for an SPD matrix and < λ₁).
    pub lam_min: f64,
    /// Estimate strictly above the largest eigenvalue.
    pub lam_max: f64,
    /// Hard cap on iterations (defaults to the dimension).
    pub max_iters: usize,
    pub reorth: Reorth,
}

impl GqlOptions {
    pub fn new(lam_min: f64, lam_max: f64) -> Self {
        GqlOptions { lam_min, lam_max, max_iters: usize::MAX, reorth: Reorth::None }
    }

    pub fn with_max_iters(mut self, it: usize) -> Self {
        self.max_iters = it;
        self
    }

    pub fn with_reorth(mut self, r: Reorth) -> Self {
        self.reorth = r;
        self
    }
}

/// The four bound estimates after an iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Bounds {
    /// 1-based iteration index that produced these bounds.
    pub iter: usize,
    /// Gauss estimate (lower bound).
    pub gauss: f64,
    /// Right Gauss-Radau (tighter lower bound; Thm. 4).
    pub radau_lower: f64,
    /// Left Gauss-Radau (tighter upper bound; Thm. 6).
    pub radau_upper: f64,
    /// Gauss-Lobatto (upper bound).
    pub lobatto: f64,
    /// True once the Krylov space is exhausted (all four values exact).
    pub exact: bool,
}

impl Bounds {
    /// Best available lower bound.
    #[inline]
    pub fn lower(&self) -> f64 {
        self.radau_lower.max(self.gauss)
    }

    /// Best available upper bound.
    #[inline]
    pub fn upper(&self) -> f64 {
        if self.exact {
            self.gauss
        } else {
            self.radau_upper.min(self.lobatto)
        }
    }

    /// Width of the bracket.
    #[inline]
    pub fn gap(&self) -> f64 {
        self.upper() - self.lower()
    }

    /// Midpoint estimate (used as fallback when a judge hits its budget).
    #[inline]
    pub fn mid(&self) -> f64 {
        0.5 * (self.lower() + self.upper())
    }
}

/// Incremental GQL state over a [`SymOp`].
pub struct Gql<'a> {
    op: &'a dyn SymOp,
    opts: GqlOptions,
    n: usize,

    // Lanczos vectors (preallocated; never reallocated)
    v_prev: Vec<f64>,
    v_curr: Vec<f64>,
    w: Vec<f64>,

    /// recurrence + reorthogonalization state (shared with block lanes)
    core: LaneCore,
}

impl<'a> Gql<'a> {
    /// Start a GQL run on `u^T op^{-1} u`. `u` must be nonzero.
    ///
    /// `opts.max_iters` is clamped to the operator dimension (the Krylov
    /// space is exhausted after at most `n` steps — Lemma 15 — so larger
    /// budgets can never be spent) and floored at 1.
    pub fn new(op: &'a dyn SymOp, u: &[f64], mut opts: GqlOptions) -> Self {
        let n = op.dim();
        opts.max_iters = opts.max_iters.min(n).max(1);
        assert_eq!(u.len(), n, "dimension mismatch");
        assert!(
            opts.lam_min > 0.0 && opts.lam_max > opts.lam_min,
            "need 0 < lam_min < lam_max (got {} .. {})",
            opts.lam_min,
            opts.lam_max
        );
        let unorm2: f64 = u.iter().map(|x| x * x).sum();
        assert!(unorm2 > 0.0, "u must be nonzero");
        let inv_norm = 1.0 / unorm2.sqrt();
        let v_curr: Vec<f64> = u.iter().map(|x| x * inv_norm).collect();
        Gql {
            op,
            opts,
            n,
            v_prev: vec![0.0; n],
            v_curr,
            w: vec![0.0; n],
            core: LaneCore::new(&opts, unorm2),
        }
    }

    pub fn iterations(&self) -> usize {
        self.core.iterations()
    }

    pub fn is_exhausted(&self) -> bool {
        self.core.is_exhausted()
    }

    pub fn last_bounds(&self) -> Option<Bounds> {
        self.core.last_bounds()
    }

    /// One quadrature iteration: one matvec + O(1) recurrences (+ O(n·i)
    /// when reorthogonalizing). Returns the updated bounds; after
    /// exhaustion (where the stored bounds are exact — breakdown or
    /// `iter == n`), keeps returning them.
    pub fn step(&mut self) -> Bounds {
        if self.core.is_exhausted() || self.core.iterations() >= self.opts.max_iters {
            return self
                .core
                .last_bounds()
                .expect("step after exhaustion requires a prior step");
        }
        self.op.matvec(&self.v_curr, &mut self.w);
        // width-1 panel column 0 ≡ the scalar layout (see
        // quadrature::recurrence for the full op sequence)
        self.core
            .step_column(&mut self.v_prev, &mut self.v_curr, &mut self.w, self.n, 1, 0)
    }

    /// Run `k` iterations (or until exhaustion) collecting the history.
    pub fn run(&mut self, k: usize) -> Vec<Bounds> {
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            out.push(self.step());
            if self.core.is_exhausted() {
                break;
            }
        }
        out
    }

    /// Iterate until the bracket width drops below `tol` (absolute) or the
    /// space is exhausted; returns the final bounds.
    pub fn run_to_gap(&mut self, tol: f64) -> Bounds {
        loop {
            let b = self.step();
            if b.exact || b.gap() <= tol || self.core.iterations() >= self.opts.max_iters {
                return b;
            }
        }
    }
}

/// One-shot convenience: bounds on `u^T A^{-1} u` after `k` iterations.
pub fn bif_bounds(op: &dyn SymOp, u: &[f64], opts: GqlOptions, k: usize) -> Bounds {
    let mut q = Gql::new(op, u, opts);
    let mut last = q.step();
    for _ in 1..k {
        if q.is_exhausted() {
            break;
        }
        last = q.step();
    }
    last
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::linalg::{sym_eigenvalues, Cholesky, DMat};
    use crate::util::prop::{assert_close, assert_le, forall};
    use crate::util::rng::Rng;

    /// Paper §4.4 generator: random symmetric, density-masked, diagonal
    /// shifted so λ₁ = lam1. Returns (A, λ₁, λ_N).
    pub fn random_shifted_spd(rng: &mut Rng, n: usize, density: f64, lam1: f64) -> (DMat, f64, f64) {
        let mut a = DMat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                if i == j || rng.bool(density) {
                    let v = rng.normal();
                    a.set(i, j, v);
                    a.set(j, i, v);
                }
            }
        }
        let ev = sym_eigenvalues(&a);
        a.shift_diag(lam1 - ev[0]);
        (a, lam1, ev[n - 1] - ev[0] + lam1)
    }

    fn setup(rng: &mut Rng, n: usize) -> (DMat, Vec<f64>, f64, f64, f64) {
        let (a, l1, ln) = random_shifted_spd(rng, n, 0.5, 0.1);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        (a, u, l1, ln, exact)
    }

    #[test]
    fn identity_matrix_is_exact_at_iteration_one() {
        let a = DMat::eye(8);
        let u = vec![1.0; 8];
        let mut q = Gql::new(&a, &u, GqlOptions::new(0.5, 2.0));
        let b = q.step();
        assert!(b.exact);
        assert_close(b.gauss, 8.0, 1e-14, 0.0);
    }

    #[test]
    fn bounds_sandwich_exact_value() {
        forall(25, 0x601, |rng| {
            let n = 4 + rng.below(28);
            let (a, u, l1, ln, exact) = setup(rng, n);
            let opts = GqlOptions::new(l1 * 0.99, ln * 1.01);
            let mut q = Gql::new(&a, &u, opts);
            let tol = 1e-7 * exact.abs();
            for b in q.run(n) {
                assert_le(b.gauss, exact, tol);
                assert_le(b.radau_lower, exact, tol);
                assert_le(exact, b.radau_upper, tol);
                assert_le(exact, b.lobatto, tol);
            }
        });
    }

    #[test]
    fn monotone_and_ordered_corr7_thm4_thm6() {
        forall(25, 0x602, |rng| {
            let n = 6 + rng.below(24);
            let (a, u, l1, ln, exact) = setup(rng, n);
            let opts = GqlOptions::new(l1 * 0.99, ln * 1.01);
            let mut q = Gql::new(&a, &u, opts);
            let hist = q.run(n - 1);
            let tol = 1e-8 * exact.abs().max(1.0);
            for w in hist.windows(2) {
                let (p, c) = (w[0], w[1]);
                if c.exact {
                    break;
                }
                // Corr. 7 monotonicity
                assert_le(p.gauss, c.gauss, tol);
                assert_le(p.radau_lower, c.radau_lower, tol);
                assert_le(c.radau_upper, p.radau_upper, tol);
                assert_le(c.lobatto, p.lobatto, tol);
                // Thm. 4: g_i ≤ g_i^rr ≤ g_{i+1}
                assert_le(p.gauss, p.radau_lower, tol);
                assert_le(p.radau_lower, c.gauss, tol);
                // Thm. 6: g_{i+1}^lo ≤ g_i^lr ≤ g_i^lo
                assert_le(c.lobatto, p.radau_upper, tol);
                assert_le(p.radau_upper, p.lobatto, tol);
            }
        });
    }

    #[test]
    fn converges_to_exact_at_dimension() {
        forall(20, 0x603, |rng| {
            let n = 3 + rng.below(20);
            let (a, u, l1, ln, exact) = setup(rng, n);
            let mut q = Gql::new(&a, &u, GqlOptions::new(l1 * 0.999, ln * 1.001));
            let hist = q.run(n);
            let last = hist.last().unwrap();
            assert_close(last.gauss, exact, 1e-6, 1e-9);
        });
    }

    #[test]
    fn gauss_rate_thm3() {
        // relative error ≤ 2((√κ−1)/(√κ+1))^i
        forall(10, 0x604, |rng| {
            let n = 24;
            let (a, u, l1, ln, exact) = setup(rng, n);
            let kappa = ln / l1;
            let rho = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
            let mut q = Gql::new(&a, &u, GqlOptions::new(l1 * 0.999, ln * 1.001));
            for b in q.run(n - 1) {
                let bound = 2.0 * rho.powi(b.iter as i32) + 1e-9;
                assert_le((exact - b.gauss) / exact, bound, 0.0);
                assert_le((exact - b.radau_lower) / exact, bound, 0.0); // Thm. 5
            }
        });
    }

    #[test]
    fn radau_upper_rate_thm8() {
        forall(10, 0x605, |rng| {
            let n = 24;
            let (a, u, l1, ln, exact) = setup(rng, n);
            let lam_min = l1 * 0.99;
            let kappa = ln / l1;
            let kappa_plus = ln / lam_min;
            let rho = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
            let mut q = Gql::new(&a, &u, GqlOptions::new(lam_min, ln * 1.01));
            for b in q.run(n - 1) {
                if b.exact {
                    break;
                }
                let bound = 2.0 * kappa_plus * rho.powi(b.iter as i32) + 1e-9;
                assert_le((b.radau_upper - exact) / exact, bound, 0.0);
                // Corr. 9 for Lobatto (one power weaker)
                let bound_lo = 2.0 * kappa_plus * rho.powi(b.iter as i32 - 1) + 1e-9;
                assert_le((b.lobatto - exact) / exact, bound_lo, 0.0);
            }
        });
    }

    #[test]
    fn run_to_gap_reaches_tolerance() {
        let mut rng = Rng::new(0x606);
        let (a, u, l1, ln, exact) = setup(&mut rng, 32);
        let mut q = Gql::new(&a, &u, GqlOptions::new(l1 * 0.99, ln * 1.01));
        let b = q.run_to_gap(1e-3 * exact.abs());
        assert!(b.gap() <= 1e-3 * exact.abs() || b.exact);
        assert!(b.lower() <= exact * (1.0 + 1e-9));
        assert!(b.upper() >= exact * (1.0 - 1e-9));
    }

    #[test]
    fn reorthogonalization_stays_valid_longer() {
        // On an ill-conditioned matrix, plain Lanczos loses orthogonality;
        // both variants must still produce valid *final* values, and full
        // reorth must match the exact BIF tightly at exhaustion.
        let mut rng = Rng::new(0x607);
        let n = 40;
        let (a, _, ln, ) = {
            let (a, l1, ln) = random_shifted_spd(&mut rng, n, 1.0, 1e-4);
            (a, l1, ln)
        };
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let opts = GqlOptions::new(1e-5, ln * 1.1).with_reorth(Reorth::Full);
        let mut q = Gql::new(&a, &u, opts);
        let hist = q.run(n);
        let last = hist.last().unwrap();
        assert_close(last.gauss, exact, 1e-5, 1e-8);
    }

    #[test]
    fn max_iters_respected() {
        let mut rng = Rng::new(0x608);
        let (a, u, l1, ln, _) = setup(&mut rng, 16);
        let opts = GqlOptions::new(l1 * 0.99, ln * 1.01).with_max_iters(3);
        let mut q = Gql::new(&a, &u, opts);
        for _ in 0..10 {
            q.step();
        }
        assert_eq!(q.iterations(), 3);
    }

    #[test]
    fn max_iters_clamped_to_dimension() {
        let mut rng = Rng::new(0x609);
        let (a, u, l1, ln, _) = setup(&mut rng, 12);
        // default budget is usize::MAX; Krylov exhaustion caps useful work
        // at n, so the constructor clamps
        let q = Gql::new(&a, &u, GqlOptions::new(l1 * 0.99, ln * 1.01));
        assert_eq!(q.opts.max_iters, 12);
        let q0 = Gql::new(&a, &u, GqlOptions::new(l1 * 0.99, ln * 1.01).with_max_iters(0));
        assert_eq!(q0.opts.max_iters, 1, "floor at one iteration");
    }

    #[test]
    #[should_panic(expected = "u must be nonzero")]
    fn zero_vector_rejected() {
        let a = DMat::eye(4);
        let _ = Gql::new(&a, &[0.0; 4], GqlOptions::new(0.5, 2.0));
    }

    #[test]
    #[should_panic(expected = "need 0 < lam_min")]
    fn bad_window_rejected() {
        let a = DMat::eye(4);
        let _ = Gql::new(&a, &[1.0; 4], GqlOptions::new(-1.0, 2.0));
    }
}
