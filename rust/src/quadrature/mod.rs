//! The paper's core: Gauss-Quadrature-Lanczos bounds on bilinear inverse
//! forms, the shared recurrence module both engines drive
//! ([`recurrence`] — the single owner of the Sherman–Morrison update,
//! Radau/Lobatto corrections, and breakdown detection), the block engine
//! that batches many such runs over one shared operator, the unified
//! query planner ([`query`] — a [`Session`] compiles an arbitrary mix of
//! estimate/threshold/compare/argmax queries onto shared panels), the
//! multi-operator streaming engine ([`engine`] — an always-on scheduler
//! running every live session jointly: streaming submission, a global
//! lane budget with bit-identical query suspend/resume, TTL eviction,
//! and parallel panel sweeps), the racing scheduler ([`race`], now a
//! thin wrapper over the planner), the stochastic Lanczos quadrature
//! layer ([`stochastic`] — trace/logdet/spectral-sum estimation over
//! panels of random probes with a two-interval error report), the
//! retrospective judges built on them, conjugate gradients (both a
//! baseline and the theory cross-check of Thm. 12), and Jacobi
//! preconditioning (§5.4).

pub mod block;
pub mod cg;
pub mod engine;
pub mod gql;
pub mod judge;
pub mod precond;
pub mod query;
pub mod race;
pub mod recurrence;
pub mod stochastic;

pub use block::{
    block_solve, run_scalar, BlockGql, BlockResult, RetireEvent, RetireReason, StopRule,
};
pub use cg::{cg_solve, CgResult};
pub use engine::{
    race_dg_joint, DgSideSpec, Engine, EngineConfig, EngineConfigError, EngineStats, OpKey,
    OpStore, RoundProfile, SubmitError, SweepMode, Ticket, TicketError,
};
pub use gql::{bif_bounds, Bounds, Gql, GqlOptions, Reorth};
pub use judge::{
    judge_dg, judge_ratio, judge_ratio_block, judge_ratio_policy, judge_threshold,
    judge_threshold_src, BoundSource, JudgeOutcome, JudgeStats, RefinePolicy,
};
pub use precond::JacobiPrecond;
pub use query::{Answer, Query, QueryArm, Session, SessionStats};
pub use race::{race_dg, Race, RaceOutcome, RacePolicy, RaceStats};
pub use recurrence::{LaneCore, Recurrence};
pub use stochastic::{
    probe_vector, summarize, t_critical_95, Interval, ProbeBracket, ProbeDist, SlqConfig,
    SlqConfigError, SlqSummary, SpectralFn, StochasticReport,
};

/// Exact-zero query detection, shared by the engines, judges, and the
/// racing scheduler: a zero `u` has BIF exactly 0 (no quadrature lane is
/// spent on it), and all three callers must agree on what counts as zero
/// or their exactness contracts diverge.
#[inline]
pub(crate) fn is_zero(u: &[f64]) -> bool {
    u.iter().all(|&x| x == 0.0)
}
