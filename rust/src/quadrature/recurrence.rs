//! The single source of truth for the GQL inner loop: the Sherman–Morrison
//! `[J_i^{-1}]_{1,1}` recurrence, the Radau/Lobatto correction formulas,
//! breakdown detection, and the per-column Lanczos panel step.
//!
//! Both drivers — the scalar [`crate::quadrature::Gql`] and the lockstep
//! lanes of [`crate::quadrature::block::BlockGql`] — advance a [`LaneCore`]
//! and never touch the recurrence arithmetic themselves, so the
//! floating-point op sequence exists in exactly one place and the block
//! engine's bit-exactness contract holds **by construction**: a width-1
//! interleaved panel (`x[i * 1 + 0]`) is literally the scalar memory
//! layout, and every wider panel runs the same per-column op order. The
//! regression tests in `rust/tests/prop_recurrence.rs` additionally pin
//! the sequence against a frozen transcription of the pre-extraction
//! arithmetic (the two hand-synchronized copies this module replaced).
//!
//! Grep contract (ISSUE 2 acceptance): `d_lr`/`d_rr` arithmetic appears
//! only in this file; everything else forwards through [`Recurrence`] and
//! [`LaneCore`].

use super::gql::{Bounds, GqlOptions, Reorth};

/// Breakdown threshold relative to the Ritz scale: a `beta` at or below
/// `BREAKDOWN_TOL * max(|alpha|, 1)` means the Krylov space is exhausted
/// and the Gauss value is exact (Lemma 15).
pub(crate) const BREAKDOWN_TOL: f64 = 1e-13;

/// Sherman–Morrison recurrence state for one quadrature lane: the Gauss
/// estimate `g`, the auxiliary product `c`, the tridiagonal pivot `delta`,
/// the modified-Jacobi pivots `d_lr`/`d_rr` (left/right Gauss-Radau), the
/// previous off-diagonal `beta_prev`, and the query norm `unorm2`.
/// [`Recurrence::step`] is the only place these fields are combined
/// arithmetically.
#[derive(Clone, Debug)]
pub struct Recurrence {
    lam_min: f64,
    lam_max: f64,
    unorm2: f64,
    beta_prev: f64,
    g: f64,
    c: f64,
    delta: f64,
    d_lr: f64,
    d_rr: f64,
    iter: usize,
}

impl Recurrence {
    /// Fresh state for a query of squared norm `unorm2` (> 0) against an
    /// operator whose spectrum lies in `(lam_min, lam_max)`.
    pub fn new(lam_min: f64, lam_max: f64, unorm2: f64) -> Self {
        Recurrence {
            lam_min,
            lam_max,
            unorm2,
            beta_prev: 0.0,
            g: 0.0,
            c: 1.0,
            delta: 0.0,
            d_lr: 0.0,
            d_rr: 0.0,
            iter: 0,
        }
    }

    /// 1-based count of recurrence steps taken so far.
    #[inline]
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// Off-diagonal from the previous Lanczos step (0.0 before the first):
    /// the drivers need it for the three-term vector update *before* this
    /// iteration's `beta` exists.
    #[inline]
    pub fn beta_prev(&self) -> f64 {
        self.beta_prev
    }

    /// Squared norm of the query vector this lane was seeded with. The
    /// stochastic quadrature layer scales `e₁ᵀ f(T_k) e₁` by this to
    /// recover `uᵀ f(A) u`.
    #[inline]
    pub fn unorm2(&self) -> f64 {
        self.unorm2
    }

    /// Advance one iteration given the fresh Lanczos coefficients
    /// `(alpha, beta)`: update the Sherman–Morrison state, detect
    /// breakdown, and return the four-bound snapshot plus the breakdown
    /// flag. On breakdown (`true`) the bounds collapse onto the now-exact
    /// Gauss value and `beta_prev` is *not* advanced — the lane is dead.
    pub fn step(&mut self, alpha: f64, beta: f64) -> (Bounds, bool) {
        self.iter += 1;
        if self.iter == 1 {
            self.g = self.unorm2 / alpha;
            self.c = 1.0;
            self.delta = alpha;
            self.d_lr = alpha - self.lam_min;
            self.d_rr = alpha - self.lam_max;
        } else {
            let bp2 = self.beta_prev * self.beta_prev;
            self.g += self.unorm2 * bp2 * self.c * self.c
                / (self.delta * (alpha * self.delta - bp2));
            self.c *= self.beta_prev / self.delta;
            let delta_new = alpha - bp2 / self.delta;
            self.d_lr = alpha - self.lam_min - bp2 / self.d_lr;
            self.d_rr = alpha - self.lam_max - bp2 / self.d_rr;
            self.delta = delta_new;
        }
        let breakdown = !(beta > BREAKDOWN_TOL * alpha.abs().max(1.0));
        let bounds = if breakdown {
            // Krylov space exhausted: the Gauss value is the exact BIF
            // (Lemma 15); all four bounds collapse onto it.
            Bounds {
                iter: self.iter,
                gauss: self.g,
                radau_lower: self.g,
                radau_upper: self.g,
                lobatto: self.g,
                exact: true,
            }
        } else {
            let (g_rr, g_lr, g_lo) = self.corrections(beta);
            Bounds {
                iter: self.iter,
                gauss: self.g,
                radau_lower: g_rr,
                radau_upper: g_lr,
                lobatto: g_lo,
                exact: false,
            }
        };
        if !breakdown {
            self.beta_prev = beta;
        }
        (bounds, breakdown)
    }

    /// Radau/Lobatto corrections from the current recurrence state and the
    /// fresh off-diagonal `beta` (see python/compile/kernels/ref.py for
    /// the Lobatto coefficient derivation; the paper's Alg. 5 rendering is
    /// OCR-mangled there).
    fn corrections(&self, beta: f64) -> (f64, f64, f64) {
        let (lam_min, lam_max) = (self.lam_min, self.lam_max);
        let beta2 = beta * beta;
        let a_lr = lam_min + beta2 / self.d_lr;
        let a_rr = lam_max + beta2 / self.d_rr;
        let denom = self.d_rr - self.d_lr;
        let b_lo2 = (lam_max - lam_min) * self.d_lr * self.d_rr / denom;
        let a_lo = (lam_max * self.d_rr - lam_min * self.d_lr) / denom;
        let c2 = self.c * self.c;
        let k = self.unorm2 * c2 / self.delta;
        let g_rr = self.g + k * beta2 / (a_rr * self.delta - beta2);
        let g_lr = self.g + k * beta2 / (a_lr * self.delta - beta2);
        let g_lo = self.g + k * b_lo2 / (a_lo * self.delta - b_lo2);
        (g_rr, g_lr, g_lo)
    }
}

/// One quadrature lane minus its Lanczos vectors (those live in the
/// driver's panel buffers): recurrence state, the optional
/// reorthogonalization basis, and exhaustion tracking.
///
/// [`LaneCore::step_column`] performs the complete per-iteration op
/// sequence of the scalar engine on column `l` of an interleaved
/// width-`b` panel; `b = 1, l = 0` *is* the scalar layout, which is what
/// makes scalar/block bit-identity structural rather than tested-for.
#[derive(Clone, Debug)]
pub struct LaneCore {
    rec: Recurrence,
    reorth: Reorth,
    /// stored (deinterleaved) Lanczos basis when reorthogonalizing
    basis: Vec<Vec<f64>>,
    exhausted: bool,
    last: Option<Bounds>,
    /// opt-in `(alpha, beta)` transcript of the Jacobi matrix built so
    /// far; `None` (the default) records nothing. Recording is pure
    /// observation — the recurrence arithmetic is untouched, so enabling
    /// it cannot move a bit in any bound.
    jacobi: Option<Vec<(f64, f64)>>,
}

impl LaneCore {
    /// Fresh lane over a query of squared norm `unorm2` (> 0). Only
    /// `lam_min`, `lam_max`, and `reorth` are read from `opts`; iteration
    /// budgets stay with the driver.
    pub fn new(opts: &GqlOptions, unorm2: f64) -> Self {
        LaneCore {
            rec: Recurrence::new(opts.lam_min, opts.lam_max, unorm2),
            reorth: opts.reorth,
            basis: Vec::new(),
            exhausted: false,
            last: None,
            jacobi: None,
        }
    }

    /// Start (or stop) recording the per-step Lanczos coefficients. The
    /// stochastic quadrature layer needs the full tridiagonal `T_k` to
    /// evaluate `e₁ᵀ f(T_k) e₁` for non-inverse spectral functions; lanes
    /// that never ask pay nothing.
    pub fn set_record_jacobi(&mut self, yes: bool) {
        if yes {
            self.jacobi.get_or_insert_with(Vec::new);
        } else {
            self.jacobi = None;
        }
    }

    /// The recorded `(alpha_i, beta_i)` Jacobi coefficients, if recording
    /// was enabled. `beta_i` is the off-diagonal *produced by* step `i`
    /// (the residual norm), so the k-step tridiagonal uses
    /// `alpha_1..alpha_k` and `beta_1..beta_{k-1}`.
    #[inline]
    pub fn jacobi(&self) -> Option<&[(f64, f64)]> {
        self.jacobi.as_deref()
    }

    /// Squared norm of this lane's query vector (see
    /// [`Recurrence::unorm2`]).
    #[inline]
    pub fn unorm2(&self) -> f64 {
        self.rec.unorm2()
    }

    /// Quadrature iterations performed.
    #[inline]
    pub fn iterations(&self) -> usize {
        self.rec.iterations()
    }

    /// True once the Krylov space is exhausted (breakdown or `iter == n`);
    /// the lane must not be stepped further.
    #[inline]
    pub fn is_exhausted(&self) -> bool {
        self.exhausted
    }

    /// Bounds from the most recent step, if any.
    #[inline]
    pub fn last_bounds(&self) -> Option<Bounds> {
        self.last
    }

    /// One quadrature iteration on panel column `l`, given `w = A v_curr`
    /// already computed by the driver (one scalar matvec or one lane of a
    /// `matvec_multi` sweep): the three-term Lanczos update, optional
    /// two-pass Gram–Schmidt against the stored basis, the
    /// Sherman–Morrison step, breakdown detection, and the vector
    /// advance. Bounds are marked `exact` once the Krylov space is full
    /// (`iter == n`), with or without a breakdown, so downstream
    /// [`Bounds::upper`] collapses to the exact Gauss value.
    pub fn step_column(
        &mut self,
        v_prev: &mut [f64],
        v_curr: &mut [f64],
        w: &mut [f64],
        n: usize,
        b: usize,
        l: usize,
    ) -> Bounds {
        debug_assert!(!self.exhausted, "stepping an exhausted lane");
        debug_assert!(l < b && v_curr.len() >= n * b && w.len() >= n * b);
        // alpha = v_curr · w on column l (sequential accumulation — the
        // scalar engine's order, for every panel width)
        let mut alpha = 0.0;
        for i in 0..n {
            alpha += v_curr[i * b + l] * w[i * b + l];
        }
        let beta_prev = self.rec.beta_prev();
        for i in 0..n {
            let k = i * b + l;
            w[k] -= alpha * v_curr[k] + beta_prev * v_prev[k];
        }
        if self.reorth == Reorth::Full {
            if self.basis.is_empty() {
                self.basis.push((0..n).map(|i| v_curr[i * b + l]).collect());
            }
            for _pass in 0..2 {
                for q in &self.basis {
                    let mut proj = 0.0;
                    for i in 0..n {
                        proj += q[i] * w[i * b + l];
                    }
                    for i in 0..n {
                        w[i * b + l] -= proj * q[i];
                    }
                }
            }
        }
        let mut beta2 = 0.0;
        for i in 0..n {
            let wk = w[i * b + l];
            beta2 += wk * wk;
        }
        let beta = beta2.sqrt();
        if let Some(j) = self.jacobi.as_mut() {
            j.push((alpha, beta));
        }

        let (mut bounds, breakdown) = self.rec.step(alpha, beta);
        if breakdown {
            self.exhausted = true;
        } else {
            // advance the lane's Lanczos column in place
            let inv_beta = 1.0 / beta;
            for i in 0..n {
                let k = i * b + l;
                v_prev[k] = v_curr[k];
                v_curr[k] = w[k] * inv_beta;
            }
            if self.reorth == Reorth::Full {
                self.basis.push((0..n).map(|i| v_curr[i * b + l]).collect());
            }
        }
        if self.rec.iterations() >= n {
            // Krylov space full: the value is exact even without a
            // breakdown flag (previously the emitted Bounds carried
            // `exact: false` here and Bounds::upper() kept returning a
            // Radau value — ISSUE 2 satellite).
            self.exhausted = true;
            bounds.exact = true;
        }
        self.last = Some(bounds);
        bounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> GqlOptions {
        GqlOptions::new(0.5, 2.0)
    }

    #[test]
    fn first_step_seeds_the_recurrence() {
        let mut r = Recurrence::new(0.5, 2.0, 4.0);
        // alpha = 1 (identity), beta = 0 → breakdown, g = unorm2 / alpha
        let (b, broke) = r.step(1.0, 0.0);
        assert!(broke);
        assert!(b.exact);
        assert_eq!(b.gauss, 4.0);
        assert_eq!(b.radau_upper, 4.0);
        assert_eq!(r.iterations(), 1);
    }

    #[test]
    fn beta_prev_only_advances_without_breakdown() {
        let mut r = Recurrence::new(0.5, 2.0, 1.0);
        let (_, broke) = r.step(1.0, 0.25);
        assert!(!broke);
        assert_eq!(r.beta_prev(), 0.25);
        let (_, broke) = r.step(1.1, 0.0);
        assert!(broke);
        assert_eq!(r.beta_prev(), 0.25, "dead lane keeps its last beta");
    }

    #[test]
    fn lane_core_marks_exact_at_dimension() {
        // 2x2 identity-ish operator driven by hand: after n = 2 steps the
        // emitted bounds must carry exact = true even without a breakdown
        let o = opts();
        let mut core = LaneCore::new(&o, 2.0);
        let n = 2;
        let mut v_prev = vec![0.0; n];
        let mut v_curr = vec![std::f64::consts::FRAC_1_SQRT_2; n];
        // A = diag(1.0, 1.2): w = A v
        let a = [1.0, 1.2];
        let mut w: Vec<f64> = v_curr.iter().zip(a).map(|(x, d)| x * d).collect();
        let b1 = core.step_column(&mut v_prev, &mut v_curr, &mut w, n, 1, 0);
        assert!(!b1.exact);
        assert!(!core.is_exhausted());
        let mut w: Vec<f64> = v_curr.iter().zip(a).map(|(x, d)| x * d).collect();
        let b2 = core.step_column(&mut v_prev, &mut v_curr, &mut w, n, 1, 0);
        assert!(b2.exact, "Krylov space full at iter == n");
        assert!(core.is_exhausted());
        assert_eq!(core.last_bounds().unwrap().iter, 2);
    }
}
