//! Stochastic Lanczos quadrature (SLQ): Hutchinson estimators for
//! `tr f(A)` — trace of the inverse, log-determinant, and general
//! spectral sums — built from the same per-lane Gauss/Radau/Lobatto
//! machinery the bilinear-form queries use.
//!
//! A probe vector `z` with `E[zzᵀ] = I` (Rademacher or Gaussian) gives
//! `E[zᵀ f(A) z] = tr f(A)`, and each quadratic form `zᵀ f(A) z` is a
//! Riemann–Stieltjes integral the lane's Jacobi matrix brackets from
//! both sides (Golub–Meurant; the monotone block-Gauss view of
//! Zimmerling–Druskin–Simoncini, arXiv 2407.21505). The subsystem
//! therefore reports **two nested intervals** per query:
//!
//! * a *deterministic envelope* — the mean of the per-probe quadrature
//!   brackets, which certainly contains the mean of the probes' exact
//!   quadratic forms, and
//! * a *combined interval* — the envelope widened by a two-sided 95%
//!   Student-t confidence radius on the per-probe midpoints, which
//!   covers `tr f(A)` itself up to the Monte-Carlo confidence level.
//!
//! For `f = 1/x` the lane's own Sherman–Morrison bounds are reused
//! directly ([`bracket_from_bounds`]). For other spectral functions the
//! lane records its recurrence coefficients
//! ([`LaneCore::set_record_jacobi`](super::recurrence::LaneCore)) and
//! [`bracket_from_transcript`] rebuilds the Gauss rule plus the
//! Radau/Lobatto modifications from the transcript: prescribed-node
//! extensions of the Jacobi matrix evaluated through the O(k²)
//! first-row eigensolver ([`tridiag_eig_weights`]). Which rule bounds
//! from which side depends on the derivative signs of `f`
//! ([`SpectralFn::sides`]); the module's property tests pin each
//! orientation against exact diagonal references.
//!
//! Probe vectors are a pure function of `(seed, probe index)` through
//! [`Rng::stream`], so an SLQ answer is bit-identical under any worker
//! count or sweep mode — determinism is inherited from the block
//! engine's exactness contract, not re-established per run.

use super::gql::Bounds;
use crate::linalg::tridiag_eig_weights;
use crate::util::rng::Rng;
use std::fmt;

/// Spectral function inside `tr f(A)` / `zᵀ f(A) z`. All variants are
/// smooth on `(0, ∞)`, the spectrum of the SPD operators the engine
/// serves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpectralFn {
    /// `f(x) = 1/x` — trace of the inverse (paper's bilinear form with
    /// random probes).
    Inverse,
    /// `f(x) = ln x` — `tr log A = logdet A`.
    Log,
    /// `f(x) = eˣ` — heat-kernel / Estrada-style sums.
    Exp,
    /// `f(x) = xᵖ` for `p ∈ (−∞, 0) ∪ (0, 1)` (Schatten-type sums;
    /// other exponents are rejected by [`SpectralFn::validate`] because
    /// the quadrature error signs are not constant there).
    Power(f64),
}

impl SpectralFn {
    /// Evaluate `f` at `x`.
    #[inline]
    pub fn eval(&self, x: f64) -> f64 {
        match *self {
            SpectralFn::Inverse => 1.0 / x,
            SpectralFn::Log => x.ln(),
            SpectralFn::Exp => x.exp(),
            SpectralFn::Power(p) => x.powf(p),
        }
    }

    /// Which side each quadrature rule bounds from, encoded as
    /// `(gauss_is_lower, left_radau_is_lower)`. The Gauss error carries
    /// the sign of the even derivatives of `f`, the Radau error the
    /// sign of the odd ones (left node) or its negation (right node),
    /// and the Lobatto error the negated even sign — so Gauss/Lobatto
    /// and left/right Radau always sit on opposite sides:
    ///
    /// * `1/x` (and `xᵖ`, p < 0): even > 0, odd < 0 → Gauss and right
    ///   Radau are lower bounds (the classical BIF orientation);
    /// * `ln x` (and `xᵖ`, 0 < p < 1): even < 0, odd > 0 → fully
    ///   flipped;
    /// * `eˣ`: all derivatives > 0 → Gauss and *left* Radau are lower.
    fn sides(&self) -> (bool, bool) {
        match *self {
            SpectralFn::Inverse => (true, false),
            SpectralFn::Log => (false, true),
            SpectralFn::Exp => (true, true),
            SpectralFn::Power(p) => {
                if p < 0.0 {
                    (true, false)
                } else {
                    (false, true)
                }
            }
        }
    }

    /// Reject exponents whose quadrature error signs are not constant.
    pub fn validate(&self) -> Result<(), SlqConfigError> {
        if let SpectralFn::Power(p) = *self {
            if !p.is_finite() || p == 0.0 || p >= 1.0 {
                return Err(SlqConfigError::UnsupportedPower(p));
            }
        }
        Ok(())
    }
}

impl fmt::Display for SpectralFn {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            SpectralFn::Inverse => write!(f, "inverse"),
            SpectralFn::Log => write!(f, "log"),
            SpectralFn::Exp => write!(f, "exp"),
            SpectralFn::Power(p) => write!(f, "power({p})"),
        }
    }
}

/// Probe-vector distribution. Both satisfy `E[zzᵀ] = I`; Rademacher has
/// the smaller variance for trace estimation (Hutchinson) and is the
/// default.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ProbeDist {
    /// Entries ±1 with equal probability.
    #[default]
    Rademacher,
    /// Standard normal entries.
    Gaussian,
}

/// Configuration of one stochastic query.
#[derive(Clone, Copy, Debug)]
pub struct SlqConfig {
    /// Hutchinson probe count (≥ 1). All probes are issued at
    /// submission; adaptivity comes from early per-probe and whole-query
    /// retirement, not probe growth.
    pub probes: usize,
    /// Seed of the splittable probe stream — probe `i` is a pure
    /// function of `(seed, i)`.
    pub seed: u64,
    /// Relative tolerance on the combined interval: the query retires
    /// once `width ≤ tol · max(|estimate|, 1)` (the absolute floor
    /// protects near-zero targets such as `logdet ≈ 0`).
    pub tol: f64,
    /// Probe distribution.
    pub dist: ProbeDist,
}

impl SlqConfig {
    /// Config with the default (Rademacher) probe distribution.
    pub fn new(probes: usize, seed: u64, tol: f64) -> Self {
        SlqConfig { probes, seed, tol, dist: ProbeDist::Rademacher }
    }

    pub fn with_dist(mut self, dist: ProbeDist) -> Self {
        self.dist = dist;
        self
    }

    /// Typed validation, mirroring
    /// [`EngineConfigError`](super::engine::EngineConfigError): the
    /// engine's admission paths refuse invalid configs before a lane is
    /// spent.
    pub fn validate(&self) -> Result<(), SlqConfigError> {
        if self.probes == 0 {
            return Err(SlqConfigError::ZeroProbes);
        }
        if !self.tol.is_finite() {
            return Err(SlqConfigError::NonFiniteTol(self.tol));
        }
        if self.tol <= 0.0 {
            return Err(SlqConfigError::NonPositiveTol(self.tol));
        }
        Ok(())
    }
}

/// Rejection reasons for a stochastic query config.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SlqConfigError {
    /// `probes == 0`: an estimator with no samples has no answer.
    ZeroProbes,
    /// Tolerance is NaN or infinite.
    NonFiniteTol(f64),
    /// Tolerance must be strictly positive.
    NonPositiveTol(f64),
    /// `Power(p)` outside `(−∞, 0) ∪ (0, 1)` — quadrature bound
    /// orientation is not constant for those exponents.
    UnsupportedPower(f64),
}

impl fmt::Display for SlqConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SlqConfigError::ZeroProbes => write!(f, "slq_probes must be >= 1"),
            SlqConfigError::NonFiniteTol(t) => write!(f, "slq_tol must be finite (got {t})"),
            SlqConfigError::NonPositiveTol(t) => {
                write!(f, "slq_tol must be > 0 (got {t})")
            }
            SlqConfigError::UnsupportedPower(p) => {
                write!(f, "spectral power must lie in (-inf,0) or (0,1) (got {p})")
            }
        }
    }
}

impl std::error::Error for SlqConfigError {}

/// Probe vector `i` of the stream: a pure function of
/// `(dist, seed, i, n)` — deterministic under any worker count, sweep
/// mode, or probe-issue order.
pub fn probe_vector(dist: ProbeDist, seed: u64, index: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::stream(seed, index);
    match dist {
        ProbeDist::Rademacher => {
            (0..n).map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 }).collect()
        }
        ProbeDist::Gaussian => (0..n).map(|_| rng.normal()).collect(),
    }
}

/// Deterministic two-sided bracket on one probe's quadratic form
/// `zᵀ f(A) z`.
#[derive(Clone, Copy, Debug)]
pub struct ProbeBracket {
    pub lo: f64,
    pub hi: f64,
    /// Krylov space exhausted: `lo == hi` is the exact value.
    pub exact: bool,
}

impl ProbeBracket {
    #[inline]
    pub fn mid(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }

    #[inline]
    pub fn gap(&self) -> f64 {
        self.hi - self.lo
    }
}

/// Fraction of the query tolerance at which an individual probe's
/// bracket is tight enough to retire its lane: more Lanczos iterations
/// on that probe cannot reduce the Monte-Carlo term, so its sweeps are
/// better spent elsewhere in the panel.
pub const PROBE_GAP_FRACTION: f64 = 0.25;

/// True once `b` is tight enough (relative to its own midpoint, with
/// the same absolute floor the query tolerance uses) that refining the
/// probe further cannot help the combined interval meaningfully.
#[inline]
pub fn probe_converged(b: &ProbeBracket, tol: f64) -> bool {
    b.exact || b.gap() <= PROBE_GAP_FRACTION * tol * b.mid().abs().max(1.0)
}

/// Bracket for `f = 1/x` straight from a lane's Sherman–Morrison
/// bounds — the two computations agree (same quadrature rules), this
/// path just skips the transcript eigen-solves.
pub fn bracket_from_bounds(b: &Bounds) -> ProbeBracket {
    if b.exact {
        ProbeBracket { lo: b.gauss, hi: b.gauss, exact: true }
    } else {
        ProbeBracket { lo: b.lower(), hi: b.upper(), exact: false }
    }
}

/// Last pivot of the LDLᵀ elimination of `T − shift·I`: the only
/// quantity the Radau/Lobatto modified-matrix constructions need from
/// the shifted solves `(T − shift·I) x = e_k`, since the right-hand
/// side touches nothing until the final row.
fn last_pivot(alpha: &[f64], inner: &[f64], shift: f64) -> f64 {
    let mut c = alpha[0] - shift;
    for i in 1..alpha.len() {
        c = (alpha[i] - shift) - inner[i - 1] * inner[i - 1] / c;
    }
    c
}

/// `unorm² · Σⱼ wⱼ f(λⱼ)` over the tridiagonal `(diag, off)` — one
/// quadrature rule evaluated through the first-row eigensolver.
fn quad_sum(f: SpectralFn, diag: &[f64], off: &[f64], unorm2: f64) -> f64 {
    let (lam, w) = tridiag_eig_weights(diag, off);
    let mut s = 0.0;
    for (l, wi) in lam.iter().zip(&w) {
        s += wi * f.eval(*l);
    }
    unorm2 * s
}

/// Rebuild the four-rule bracket on `zᵀ f(A) z` from a lane's recorded
/// recurrence transcript (`jacobi[i] = (αᵢ₊₁, βᵢ₊₁)`, the coefficients
/// *produced by* step i+1 — so a k-step transcript yields `T_k` from
/// `α₁..α_k` and `β₁..β_{k−1}`, with `β_k` feeding the Radau/Lobatto
/// extensions). `lam_min`/`lam_max` are the prescribed nodes (the
/// session's [`GqlOptions`](super::gql::GqlOptions) spectrum
/// estimates); `unorm2 = ‖z‖²` scales the normalized-measure rules
/// back to the quadratic form. Returns `None` when no rule produced a
/// finite value (a not-yet-swept or numerically degenerate lane).
pub fn bracket_from_transcript(
    f: SpectralFn,
    jacobi: &[(f64, f64)],
    unorm2: f64,
    lam_min: f64,
    lam_max: f64,
    exact: bool,
) -> Option<ProbeBracket> {
    let k = jacobi.len();
    if k == 0 {
        return None;
    }
    let alpha: Vec<f64> = jacobi.iter().map(|p| p.0).collect();
    let beta: Vec<f64> = jacobi.iter().map(|p| p.1).collect();
    let inner = &beta[..k - 1];
    let gauss = quad_sum(f, &alpha, inner, unorm2);
    if exact {
        return gauss.is_finite().then_some(ProbeBracket { lo: gauss, hi: gauss, exact: true });
    }
    let beta_k = beta[k - 1];

    // Gauss–Radau at prescribed node z: solve (T_k − zI)δ = β_k² e_k and
    // append α̃ = z + δ_k with coupling β_k (Golub–Meurant).
    let radau = |z: f64| -> f64 {
        let delta_k = beta_k * beta_k / last_pivot(&alpha, inner, z);
        let mut diag = alpha.clone();
        diag.push(z + delta_k);
        let mut off = inner.to_vec();
        off.push(beta_k);
        quad_sum(f, &diag, &off, unorm2)
    };
    let r_left = radau(lam_min);
    let r_right = radau(lam_max);

    // Gauss–Lobatto: prescribe both ends via the two e_k solves.
    let lobatto = {
        let dk = 1.0 / last_pivot(&alpha, inner, lam_min);
        let mk = 1.0 / last_pivot(&alpha, inner, lam_max);
        let denom = dk - mk;
        let a_lo = (dk * lam_max - mk * lam_min) / denom;
        let b_lo2 = (lam_max - lam_min) / denom;
        let mut diag = alpha.clone();
        diag.push(a_lo);
        let mut off = inner.to_vec();
        off.push(b_lo2.max(0.0).sqrt());
        quad_sum(f, &diag, &off, unorm2)
    };

    let (gauss_lower, left_radau_lower) = f.sides();
    let mut lo: Option<f64> = None;
    let mut hi: Option<f64> = None;
    let mut put = |v: f64, is_lower: bool| {
        if !v.is_finite() {
            return;
        }
        let side = if is_lower { &mut lo } else { &mut hi };
        *side = Some(match *side {
            Some(cur) => {
                if is_lower {
                    cur.max(v)
                } else {
                    cur.min(v)
                }
            }
            None => v,
        });
    };
    put(gauss, gauss_lower);
    put(lobatto, !gauss_lower);
    put(r_left, left_radau_lower);
    put(r_right, !left_radau_lower);
    match (lo, hi) {
        // a crossed bracket means rounding collapsed the enclosure; keep
        // the interval valid by sorting the endpoints
        (Some(l), Some(h)) if l <= h => Some(ProbeBracket { lo: l, hi: h, exact: false }),
        (Some(l), Some(h)) => Some(ProbeBracket { lo: h, hi: l, exact: false }),
        _ => None,
    }
}

/// Two-sided 95% Student-t critical value by degrees of freedom
/// (`df = probes − 1`); the standard table, converging to the normal
/// 1.96 for large samples.
pub fn t_critical_95(df: usize) -> f64 {
    const T95: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    match df {
        0 => f64::INFINITY,
        1..=30 => T95[df - 1],
        31..=40 => 2.030,
        41..=60 => 2.000,
        61..=120 => 1.980,
        _ => 1.960,
    }
}

/// A closed interval.
#[derive(Clone, Copy, Debug)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    #[inline]
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }

    #[inline]
    pub fn mid(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }

    #[inline]
    pub fn contains(&self, x: f64) -> bool {
        self.lo <= x && x <= self.hi
    }
}

/// Snapshot of the estimator over the probes that currently carry a
/// bracket.
#[derive(Clone, Copy, Debug)]
pub struct SlqSummary {
    /// Mean of the per-probe bracket midpoints — the point estimate.
    pub estimate: f64,
    /// Deterministic envelope: means of the per-probe lower and upper
    /// quadrature bounds. Contains the mean of the probes' exact
    /// quadratic forms by construction.
    pub envelope: Interval,
    /// Envelope widened by the t-interval confidence radius on the
    /// midpoints — the interval reported against `tr f(A)`. With a
    /// single probe the Monte-Carlo radius is undefined and the
    /// combined interval equals the envelope (quadrature error only).
    pub combined: Interval,
    /// Standard error of the midpoint mean (`s/√m`; 0 for one probe).
    pub stderr: f64,
    /// Probes contributing a bracket.
    pub probes: usize,
    /// True once `combined.width() ≤ tol · max(|estimate|, 1)`.
    pub tol_met: bool,
}

/// Fold the current per-probe brackets into the two-interval summary.
/// `None` when no probe has produced a bracket yet.
pub fn summarize(brackets: &[ProbeBracket], tol: f64) -> Option<SlqSummary> {
    let m = brackets.len();
    if m == 0 {
        return None;
    }
    let mf = m as f64;
    let (mut lo_sum, mut hi_sum, mut mid_sum) = (0.0, 0.0, 0.0);
    for b in brackets {
        lo_sum += b.lo;
        hi_sum += b.hi;
        mid_sum += b.mid();
    }
    let envelope = Interval { lo: lo_sum / mf, hi: hi_sum / mf };
    let estimate = mid_sum / mf;
    let (stderr, radius) = if m > 1 {
        let var = brackets
            .iter()
            .map(|b| {
                let d = b.mid() - estimate;
                d * d
            })
            .sum::<f64>()
            / (mf - 1.0);
        let se = (var / mf).sqrt();
        (se, t_critical_95(m - 1) * se)
    } else {
        (0.0, 0.0)
    };
    let combined = Interval { lo: envelope.lo - radius, hi: envelope.hi + radius };
    let tol_met = combined.width() <= tol * estimate.abs().max(1.0);
    Some(SlqSummary { estimate, envelope, combined, stderr, probes: m, tol_met })
}

/// Resolved stochastic answer: the final summary plus the query's
/// accounting — carried by
/// [`Answer::Stochastic`](super::query::Answer).
#[derive(Clone, Debug)]
pub struct StochasticReport {
    /// Spectral function the query evaluated.
    pub f: SpectralFn,
    /// Point estimate of `tr f(A)`.
    pub estimate: f64,
    /// Deterministic quadrature envelope (see [`SlqSummary::envelope`]).
    pub envelope: Interval,
    /// Combined quadrature + Monte-Carlo interval (see
    /// [`SlqSummary::combined`]).
    pub combined: Interval,
    /// Standard error of the midpoint mean.
    pub stderr: f64,
    /// Probes the query issued (the configured count).
    pub probes_issued: usize,
    /// Probes whose brackets back this answer — the full count for a
    /// naturally resolved query, possibly fewer for a shed/cancelled one
    /// (the anytime property: the interval is valid over whatever
    /// contributed).
    pub probes_contributing: usize,
    /// Probes retired before Krylov exhaustion because their own bracket
    /// met [`PROBE_GAP_FRACTION`] of the tolerance.
    pub probes_retired_early: usize,
    /// Per-probe early-retirement log: `(probe index, lane iterations at
    /// retirement)`, in retirement order. Length equals
    /// `probes_retired_early` for a naturally resolved query; the flight
    /// recorder replays these as `probe_retired` events and post-mortems
    /// read which probes stopped pulling their weight, and when.
    pub retired_at: Vec<(usize, usize)>,
    /// Requested relative tolerance.
    pub tol: f64,
    /// Whether the combined interval met the tolerance.
    pub tol_met: bool,
    /// Resolution round at which the tolerance was met (`None` when the
    /// query resolved by exhaustion or shedding instead).
    pub hit_round: Option<usize>,
    /// Resolution rounds the query lived through.
    pub rounds: usize,
    /// Total Lanczos iterations across all probe lanes.
    pub iters: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quadrature::block::{BlockGql, StopRule};
    use crate::quadrature::gql::GqlOptions;
    use crate::sparse::CsrBuilder;

    /// Diagonal SPD test matrix: `zᵀ f(A) z = Σ f(dᵢ) zᵢ²` exactly, for
    /// every spectral function — the reference the orientation tests
    /// pin against.
    fn diag_csr(d: &[f64]) -> crate::sparse::Csr {
        let mut b = CsrBuilder::new(d.len());
        for (i, &v) in d.iter().enumerate() {
            b.push(i, i, v);
        }
        b.build()
    }

    fn run_transcript(
        a: &crate::sparse::Csr,
        u: &[f64],
        opts: GqlOptions,
        stop: StopRule,
    ) -> (Vec<(f64, f64)>, Bounds) {
        let mut eng = BlockGql::new(a, opts, 1);
        eng.push_recorded(u, stop);
        while eng.has_work() {
            if !eng.step_panel(a) {
                break;
            }
        }
        let r = eng.take_done().pop().expect("one lane finished");
        (r.jacobi, r.bounds)
    }

    #[test]
    fn probe_vectors_are_pure_and_distribution_shaped() {
        let a = probe_vector(ProbeDist::Rademacher, 7, 3, 64);
        let b = probe_vector(ProbeDist::Rademacher, 7, 3, 64);
        assert_eq!(a, b, "pure in (seed, index)");
        assert!(a.iter().all(|&x| x == 1.0 || x == -1.0));
        let c = probe_vector(ProbeDist::Rademacher, 7, 4, 64);
        assert_ne!(a, c, "indices decorrelate");
        let g = probe_vector(ProbeDist::Gaussian, 7, 3, 4096);
        let mean = g.iter().sum::<f64>() / g.len() as f64;
        assert!(mean.abs() < 0.1, "gaussian mean={mean}");
    }

    #[test]
    fn transcript_brackets_contain_exact_value_for_every_spectral_fn() {
        let d = [0.7, 1.3, 2.1, 2.9, 3.6, 4.4, 5.2, 6.1];
        let a = diag_csr(&d);
        let opts = GqlOptions::new(0.5, 7.0);
        let u = probe_vector(ProbeDist::Gaussian, 0xF00D, 0, d.len());
        let unorm2: f64 = u.iter().map(|x| x * x).sum();
        for f in [
            SpectralFn::Inverse,
            SpectralFn::Log,
            SpectralFn::Exp,
            SpectralFn::Power(0.5),
            SpectralFn::Power(-0.5),
        ] {
            let exact: f64 = d.iter().zip(&u).map(|(&di, &ui)| f.eval(di) * ui * ui).sum();
            for k in 1..d.len() {
                let (jac, b) = run_transcript(&a, &u, opts, StopRule::Iters(k));
                let br = bracket_from_transcript(f, &jac, unorm2, 0.5, 7.0, b.exact)
                    .expect("k-step transcript brackets");
                let slack = 1e-9 * (1.0 + exact.abs());
                assert!(
                    br.lo - slack <= exact && exact <= br.hi + slack,
                    "{f} k={k}: exact {exact} outside [{}, {}]",
                    br.lo,
                    br.hi
                );
            }
            // exhaustion collapses the bracket onto the exact value
            let (jac, b) = run_transcript(&a, &u, opts, StopRule::Exhaust);
            assert!(b.exact);
            let br = bracket_from_transcript(f, &jac, unorm2, 0.5, 7.0, true).unwrap();
            assert!(br.exact);
            assert!(
                (br.lo - exact).abs() <= 1e-8 * (1.0 + exact.abs()),
                "{f}: exhausted value {} vs exact {exact}",
                br.lo
            );
        }
    }

    #[test]
    fn inverse_transcript_bracket_matches_lane_bounds() {
        let d = [0.9, 1.7, 2.4, 3.8, 5.0, 6.3];
        let a = diag_csr(&d);
        let opts = GqlOptions::new(0.7, 7.0);
        let u = probe_vector(ProbeDist::Rademacher, 0xBEEF, 1, d.len());
        let unorm2: f64 = u.iter().map(|x| x * x).sum();
        for k in 1..d.len() {
            let (jac, b) = run_transcript(&a, &u, opts, StopRule::Iters(k));
            if b.exact {
                break;
            }
            let br =
                bracket_from_transcript(SpectralFn::Inverse, &jac, unorm2, 0.7, 7.0, false)
                    .unwrap();
            let direct = bracket_from_bounds(&b);
            let tol = 1e-7 * (1.0 + direct.hi.abs());
            assert!(
                (br.lo - direct.lo).abs() < tol && (br.hi - direct.hi).abs() < tol,
                "k={k}: transcript [{}, {}] vs lane [{}, {}]",
                br.lo,
                br.hi,
                direct.lo,
                direct.hi
            );
        }
    }

    #[test]
    fn summarize_combines_envelope_and_t_interval() {
        // three probes with exact (degenerate) brackets: pure MC spread
        let brs = [
            ProbeBracket { lo: 1.0, hi: 1.0, exact: true },
            ProbeBracket { lo: 2.0, hi: 2.0, exact: true },
            ProbeBracket { lo: 3.0, hi: 3.0, exact: true },
        ];
        let s = summarize(&brs, 0.1).unwrap();
        assert_eq!(s.probes, 3);
        assert!((s.estimate - 2.0).abs() < 1e-12);
        assert!((s.envelope.width()).abs() < 1e-12);
        // s = 1, stderr = 1/√3, radius = t(2)·stderr
        let want_se = 1.0 / 3.0_f64.sqrt();
        assert!((s.stderr - want_se).abs() < 1e-12);
        let radius = t_critical_95(2) * want_se;
        assert!((s.combined.lo - (2.0 - radius)).abs() < 1e-9);
        assert!((s.combined.hi - (2.0 + radius)).abs() < 1e-9);
        assert!(!s.tol_met);

        // one probe: combined falls back to the envelope
        let one = [ProbeBracket { lo: 4.0, hi: 4.4, exact: false }];
        let s1 = summarize(&one, 0.2).unwrap();
        assert_eq!(s1.stderr, 0.0);
        assert!((s1.combined.lo - 4.0).abs() < 1e-12);
        assert!((s1.combined.hi - 4.4).abs() < 1e-12);
        assert!(s1.tol_met, "0.4 <= 0.2 * 4.2");
        assert!(summarize(&[], 0.1).is_none());
    }

    #[test]
    fn config_validation_is_typed() {
        assert!(SlqConfig::new(8, 1, 1e-2).validate().is_ok());
        assert_eq!(SlqConfig::new(0, 1, 1e-2).validate(), Err(SlqConfigError::ZeroProbes));
        assert!(matches!(
            SlqConfig::new(4, 1, f64::NAN).validate(),
            Err(SlqConfigError::NonFiniteTol(_))
        ));
        assert_eq!(
            SlqConfig::new(4, 1, -1.0).validate(),
            Err(SlqConfigError::NonPositiveTol(-1.0))
        );
        assert!(SpectralFn::Power(0.5).validate().is_ok());
        assert!(SpectralFn::Power(-2.0).validate().is_ok());
        assert_eq!(
            SpectralFn::Power(1.5).validate(),
            Err(SlqConfigError::UnsupportedPower(1.5))
        );
        assert_eq!(
            SpectralFn::Power(0.0).validate(),
            Err(SlqConfigError::UnsupportedPower(0.0))
        );
    }

    #[test]
    fn t_table_is_monotone_toward_the_normal_limit() {
        let mut prev = f64::INFINITY;
        for df in 1..200 {
            let t = t_critical_95(df);
            assert!(t <= prev, "df={df}");
            prev = t;
        }
        assert!((t_critical_95(10_000) - 1.96).abs() < 1e-12);
    }
}
