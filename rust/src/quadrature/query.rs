//! Unified query API: a [`Session`] planner that compiles mixed BIF
//! queries onto shared [`BlockGql`] panels.
//!
//! The paper has exactly one primitive — iteratively tightening
//! Gauss/Radau/Lobatto brackets on `u^T A^{-1} u` — yet the repo grew six
//! ad-hoc entry points around it (`judge_threshold`, `judge_ratio`,
//! `judge_ratio_block`, `judge_dg`, `race_dg`, `Race`), each hand-rolling
//! its own driver loop over the recurrence core. This module inverts the
//! structure: the **panel**, not the query, is the unit of scheduling
//! (the block-quadrature view of Zimmerling–Druskin–Simoncini,
//! arXiv:2407.21505, and the batched-solve systems of Pleiss et al.,
//! arXiv:2006.11267). Callers describe *what they want decided* as a
//! [`Query`]; the [`Session`] compiles every query — whatever its kind —
//! onto one shared panel over one operator, spends `matvec_multi` sweeps
//! only while some query still needs them, and retires lanes the moment
//! their query is decided (refilling the panel from pending queries).
//!
//! Query kinds and their bound logic:
//!
//! * [`Query::Estimate`] — refine a bracket on `u^T A^{-1} u` to the
//!   lane's own [`StopRule`]; answers with the final [`Bounds`].
//! * [`Query::Threshold`] — paper Alg. 4: decide `t < u^T A^{-1} u` the
//!   moment the Radau brackets separate from `t`.
//! * [`Query::Compare`] — paper Alg. 7: decide
//!   `t < p·(v^T A^{-1} v) − u^T A^{-1} u` from two lanes advanced in
//!   lockstep, stopping at first bracket separation (the decision ladder
//!   is shared with the scalar ratio judge, so the two cannot drift).
//! * [`Query::Argmax`] — best-arm racing: N affine arm values
//!   `offset_i + scale_i · BIF_i` race through the panel; dominated arms
//!   are evicted ([`RacePolicy::Prune`]) and the query resolves as soon
//!   as a lone possible winner remains.
//!
//! **Answer identity.** Every decision is certified by the same nested
//! brackets the scalar paths use, on lanes that are *bit-identical* to
//! scalar [`Gql`](super::Gql) runs (the block engine's exactness
//! contract). Threshold decisions therefore match `judge_threshold`
//! iteration-for-iteration, compare decisions match the ratio judges
//! wherever their certified brackets decide, and argmax selections equal
//! exhaustive scoring — property-tested in `rust/tests/prop_session.rs`,
//! including mixed sessions under [`Reorth::Full`](super::Reorth) on
//! ill-conditioned kernels.
//!
//! **Adaptive prune margin.** Dominance eviction uses a relative safety
//! margin. Instead of the fixed floor [`PRUNE_MARGIN`] alone, the session
//! tracks the worst *observed* bound wiggle — the amount by which any
//! arm's bracket violated the paper's nesting monotonicity due to
//! floating-point rounding — and scales the margin with it
//! ([`Session::prune_margin`]). Well-behaved runs keep the tight fixed
//! floor; noisy runs (ill-conditioned operators without reorth) get a
//! proportionally wider margin from the first wiggle onward, protecting
//! selection identity without taxing the common case (identity remains
//! property-tested rather than proven: an eviction can precede the first
//! observed wiggle).

use super::block::{BlockGql, RetireEvent, RetireReason, StopRule};
use super::gql::{Bounds, GqlOptions};
use super::judge::{ratio_verdict, JudgeOutcome, JudgeStats};
use super::race::{PRUNE_MARGIN, RacePolicy, RaceStats};
use super::stochastic::{
    bracket_from_bounds, bracket_from_transcript, probe_converged, probe_vector, summarize,
    ProbeBracket, SlqConfig, SlqConfigError, SlqSummary, SpectralFn, StochasticReport,
};
use crate::metrics::{GapTrace, MetricsRegistry};
use crate::sparse::SymOp;

/// One candidate of a [`Query::Argmax`]: the arm's value is the affine
/// form `offset + scale · u^T A^{-1} u`, refined to `stop` when the race
/// does not decide (or prune the arm) first.
#[derive(Clone, Debug)]
pub struct QueryArm {
    pub u: Vec<f64>,
    pub stop: StopRule,
    pub offset: f64,
    pub scale: f64,
}

impl QueryArm {
    /// Arm with the DPP marginal-gain orientation `offset − BIF`.
    pub fn gain(u: Vec<f64>, stop: StopRule, offset: f64) -> Self {
        QueryArm { u, stop, offset, scale: -1.0 }
    }
}

/// One decision problem over the session's shared operator `A`. All
/// vectors are query vectors against that operator; the session owns
/// them for the lifetime of the run.
#[derive(Clone, Debug)]
pub enum Query {
    /// Bracket `u^T A^{-1} u` until `stop` fires; answers with the final
    /// bounds.
    Estimate { u: Vec<f64>, stop: StopRule },
    /// Decide `t < u^T A^{-1} u` (paper Alg. 4 semantics: stop at the
    /// first Radau separation, midpoint fallback at the budget).
    Threshold { u: Vec<f64>, t: f64 },
    /// Decide `t < p·(v^T A^{-1} v) − u^T A^{-1} u` (paper Alg. 7): both
    /// lanes advance from the same panel sweep and the query stops at the
    /// first certified separation.
    Compare { u: Vec<f64>, v: Vec<f64>, t: f64, p: f64 },
    /// Find the arm with the largest value `offset + scale · BIF`,
    /// optionally requiring it to strictly exceed `floor` (else the
    /// answer's winner is `None`).
    Argmax { arms: Vec<QueryArm>, floor: Option<f64> },
    /// Stochastic Lanczos quadrature estimate of `tr f(A)`
    /// ([`super::stochastic`]): `cfg.probes` random probe lanes race
    /// through the shared panel, each carrying a deterministic four-rule
    /// bracket on its quadratic form, and the query retires once the
    /// combined quadrature + Monte-Carlo interval meets `cfg.tol`.
    Trace { f: SpectralFn, cfg: SlqConfig },
    /// `logdet A = tr log A` — [`Query::Trace`] with `f = log`, kept as
    /// its own variant because it is the DPP-normalization /
    /// GP-marginal-likelihood workhorse.
    LogDet { cfg: SlqConfig },
}

impl Query {
    /// Typed admission validation, mirroring
    /// [`EngineConfigError`](super::engine::EngineConfigError):
    /// stochastic queries carry a probe/tolerance config that must be
    /// structurally valid before any lane is spent. Non-stochastic
    /// kinds always pass.
    pub fn validate(&self) -> Result<(), SlqConfigError> {
        match self {
            Query::Trace { f, cfg } => {
                f.validate()?;
                cfg.validate()
            }
            Query::LogDet { cfg } => cfg.validate(),
            _ => Ok(()),
        }
    }
}

/// Typed result of one [`Query`], in the same shape the legacy entry
/// points returned — the thin wrappers (`judge_threshold`,
/// `judge_ratio_block`, [`Race`](super::race::Race)) just unwrap the
/// matching variant.
#[derive(Clone, Debug)]
pub enum Answer {
    /// Final bounds of an estimate lane and the iterations it consumed.
    /// `trace` carries the lane's gap trajectory when the session records
    /// convergence traces ([`Session::record_traces`]); `None` otherwise
    /// (and for cancelled estimates, whose history is lost with the
    /// retired lane). Boxed so the common untraced answer stays small.
    Estimate { bounds: Bounds, iters: usize, trace: Option<Box<GapTrace>> },
    /// Threshold decision plus the judge accounting.
    Threshold { decision: bool, stats: JudgeStats },
    /// Compare decision plus the judge accounting (`iters` sums both
    /// lanes, like the scalar ratio judges).
    Compare { decision: bool, stats: JudgeStats },
    /// Winning arm index (push order) — `None` when every arm fell at or
    /// below the floor — with per-arm estimates (`None` for pruned arms)
    /// and the race accounting.
    Argmax { winner: Option<usize>, estimates: Vec<Option<f64>>, stats: RaceStats },
    /// Stochastic trace/logdet answer: point estimate, the deterministic
    /// quadrature envelope, the combined interval, and the probe
    /// accounting. Boxed so the common bilinear answers stay small.
    Stochastic(Box<StochasticReport>),
}

impl Answer {
    /// The boolean decision of a threshold or compare answer.
    pub fn decision(&self) -> Option<bool> {
        match self {
            Answer::Threshold { decision, .. } | Answer::Compare { decision, .. } => {
                Some(*decision)
            }
            _ => None,
        }
    }

    /// The winner of an argmax answer (`None` for other kinds).
    pub fn winner(&self) -> Option<Option<usize>> {
        match self {
            Answer::Argmax { winner, .. } => Some(*winner),
            _ => None,
        }
    }

    /// The convergence trace of a traced estimate answer (`None` for
    /// other kinds or untraced sessions).
    pub fn trace(&self) -> Option<&GapTrace> {
        match self {
            Answer::Estimate { trace, .. } => trace.as_deref(),
            _ => None,
        }
    }

    /// The report of a stochastic trace/logdet answer (`None` for other
    /// kinds).
    pub fn stochastic(&self) -> Option<&StochasticReport> {
        match self {
            Answer::Stochastic(r) => Some(r.as_ref()),
            _ => None,
        }
    }
}

/// Aggregate accounting for one session.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// Queries submitted.
    pub queries: usize,
    /// Panel lanes those queries compiled to.
    pub lanes: usize,
    /// `matvec_multi` panel sweeps performed (one traversal of the shared
    /// operator each, regardless of lane count).
    pub sweeps: usize,
    /// Argmax arms evicted by interval dominance, across all queries.
    pub pruned: usize,
    /// Argmax queries whose winner was crowned before reaching its own
    /// stop rule.
    pub decided_early: usize,
    /// The dominance margin currently in force (see
    /// [`Session::prune_margin`]).
    pub prune_margin: f64,
}

/// How much observed bound wiggle is amplified into the dominance margin:
/// the margin must comfortably exceed the worst non-monotonicity actually
/// seen, or an arm could be evicted on a bracket excursion of the same
/// magnitude that produced the wiggle.
pub const WIGGLE_HEADROOM: f64 = 8.0;

#[derive(Clone, Copy, Debug)]
enum ArmStatus {
    /// In the panel or waiting in the engine queue.
    Racing,
    /// Reached its stop rule; final value data recorded.
    Done { est: f64, lo: f64, hi: f64, iters: usize },
    /// Evicted by interval dominance — provably not the argmax.
    Pruned,
}

struct ArmState {
    lane: usize,
    offset: f64,
    scale: f64,
    status: ArmStatus,
    /// Previous value bracket, for wiggle tracking.
    prev: Option<(f64, f64)>,
}

/// Which part of its query a lane serves.
#[derive(Clone, Copy, Debug)]
enum Role {
    Single,
    CmpU,
    CmpV,
    Arm(usize),
    /// Probe `i` of a stochastic trace/logdet query.
    Probe(usize),
}

enum Spec {
    Estimate {
        lane: usize,
    },
    Threshold {
        lane: usize,
        t: f64,
    },
    Compare {
        lane_u: usize,
        lane_v: usize,
        t: f64,
        p: f64,
        /// Lanes still owned by the engine (retired on decision).
        live_u: bool,
        live_v: bool,
    },
    Argmax {
        arms: Vec<ArmState>,
        floor: Option<f64>,
        decided_early: bool,
        pruned_at: Vec<(usize, usize)>,
        /// Engine sweep count at submission — per-query sweep attribution.
        start_sweep: usize,
    },
    Stochastic {
        f: SpectralFn,
        cfg: SlqConfig,
        /// Lane id per probe (probe order = stream index order).
        lanes: Vec<usize>,
        /// `‖zᵢ‖²` per probe, scaling the normalized quadrature rules.
        unorm2: Vec<f64>,
        /// Lanes the engine still owns.
        live: Vec<bool>,
        /// Latest deterministic bracket per probe.
        brackets: Vec<Option<ProbeBracket>>,
        /// Probes retired before exhaustion (their bracket met
        /// [`super::stochastic::PROBE_GAP_FRACTION`] of the tolerance).
        retired_early: usize,
        /// `(probe index, lane iterations)` at each early retirement, in
        /// retirement order — carried into
        /// [`StochasticReport::retired_at`].
        retired_at: Vec<(usize, usize)>,
        /// Resolution rounds this query has lived through.
        rounds: usize,
        /// Standard-error trajectory, one sample per resolution round
        /// (the `stochastic.stderr` telemetry histogram).
        stderr_trace: Vec<f64>,
    },
}

struct QueryState {
    spec: Spec,
    answer: Option<Answer>,
    /// Parked by [`Session::suspend_query`]: every lane the query still
    /// owns sits suspended inside the engine and resolution is deferred
    /// until [`Session::resume_query`].
    parked: bool,
}

/// Value bracket of an arm given its BIF bounds: `value = offset +
/// scale · bif`, so the bracket endpoints swap when `scale < 0`.
fn value_bracket(offset: f64, scale: f64, b: &Bounds) -> (f64, f64) {
    let (blo, bhi) = if b.exact { (b.gauss, b.gauss) } else { (b.lower(), b.upper()) };
    let (v1, v2) = (offset + scale * blo, offset + scale * bhi);
    if v1 <= v2 {
        (v1, v2)
    } else {
        (v2, v1)
    }
}

/// Point estimate of an arm's value from finished bounds: the exact Gauss
/// value after Krylov exhaustion, the bracket midpoint otherwise — the
/// same estimator the pre-racing greedy used, so exhaustive races score
/// candidates bit-identically to the old scoring loop.
fn value_estimate(offset: f64, scale: f64, b: &Bounds) -> f64 {
    let bif = if b.exact { b.gauss } else { b.mid() };
    offset + scale * bif
}

/// Interval dominance at a relative `margin` (see
/// [`Session::prune_margin`]).
#[inline]
fn dominated(hi: f64, best_lo: f64, margin: f64) -> bool {
    hi < best_lo - margin * (1.0 + hi.abs() + best_lo.abs())
}

/// Outcome classification of a finished threshold lane, mirroring the
/// scalar judge's precedence: exhaustion first, certified separation
/// next, budget-midpoint last.
fn threshold_outcome(b: &Bounds, t: f64) -> JudgeOutcome {
    if b.exact {
        JudgeOutcome::Exact
    } else if t < b.radau_lower || t >= b.radau_upper {
        JudgeOutcome::Decided
    } else {
        JudgeOutcome::Budget
    }
}

/// The planner: submit an arbitrary mix of co-keyed queries against one
/// operator, then [`Session::run`] (or drive it sweep-by-sweep with
/// [`Session::step`]). Lanes share `matvec_multi` panel sweeps across
/// query kinds; each query resolves by its own bound logic and its lanes
/// retire immediately, refilling the panel from pending queries.
///
/// Like [`BlockGql`], a session does not hold its operator: the caller
/// passes `&dyn SymOp` into every sweeping call ([`Session::step`] /
/// [`Session::run`]) and must pass the same operator the session was
/// constructed against. This keeps sessions `'static`, which is what lets
/// the resident engine ([`crate::quadrature::engine`]) own them alongside
/// `Arc<dyn SymOp>` entries in its operator store.
pub struct Session {
    eng: BlockGql,
    policy: RacePolicy,
    /// Quadrature options the session was built with — stochastic
    /// queries re-read the spectrum estimates for their transcript
    /// brackets.
    opts: GqlOptions,
    /// Iteration budget, clamped like the engines clamp it.
    max_iters: usize,
    queries: Vec<QueryState>,
    /// Lane id (engine push order) → owning query and role.
    lane_owner: Vec<(usize, Role)>,
    /// Latest bounds per lane (mid-flight snapshot or final).
    latest: Vec<Option<Bounds>>,
    unresolved: usize,
    /// Worst observed relative bracket non-monotonicity (see module docs).
    wiggle: f64,
    /// Estimate answers carry a [`GapTrace`] (see
    /// [`Session::record_traces`]).
    trace_enabled: bool,
}

impl Session {
    /// A session sized for `op`, scheduling through a width-`width` panel
    /// (`op` is only read for its dimension here — the same operator must
    /// then be passed to every [`Session::step`] / [`Session::run`]).
    /// `opts` and `width` behave exactly as in [`BlockGql::new`];
    /// `policy` governs argmax dominance pruning
    /// ([`RacePolicy::Exhaustive`] scores every arm to its stop rule).
    pub fn new(op: &dyn SymOp, opts: GqlOptions, width: usize, policy: RacePolicy) -> Self {
        let max_iters = opts.max_iters.min(op.dim()).max(1);
        Session {
            eng: BlockGql::new(op, opts, width),
            policy,
            opts,
            max_iters,
            queries: Vec::new(),
            lane_owner: Vec::new(),
            latest: Vec::new(),
            unresolved: 0,
            wiggle: 0.0,
            trace_enabled: false,
        }
    }

    /// Opt into convergence tracing: every lane records its per-iteration
    /// bound history and resolved [`Answer::Estimate`]s carry a
    /// [`GapTrace`] of the bracket-gap trajectory. Recording happens
    /// outside the recurrence arithmetic, so traced runs stay
    /// bit-identical to untraced ones (the cost is the history `Vec` per
    /// lane). Set it before submitting queries.
    pub fn record_traces(mut self, yes: bool) -> Self {
        self.trace_enabled = yes;
        self.eng.set_record_history(yes);
        self
    }

    fn push_lane(&mut self, u: &[f64], stop: StopRule, qid: usize, role: Role) -> usize {
        self.push_lane_with(u, stop, qid, role, false)
    }

    /// [`Session::push_lane`] with opt-in recurrence-transcript
    /// recording (probe lanes of non-inverse spectral functions rebuild
    /// their brackets from the transcript).
    fn push_lane_with(
        &mut self,
        u: &[f64],
        stop: StopRule,
        qid: usize,
        role: Role,
        record_jacobi: bool,
    ) -> usize {
        let id = if record_jacobi {
            self.eng.push_recorded(u, stop)
        } else {
            self.eng.push(u, stop)
        };
        debug_assert_eq!(id, self.lane_owner.len(), "lane ids mirror push order");
        self.lane_owner.push((qid, role));
        self.latest.push(None);
        id
    }

    /// Enter a query; returns its id (submission order). Queries that are
    /// decidable without quadrature (zero vectors, empty argmax batches)
    /// resolve immediately.
    ///
    /// Stochastic queries must carry a valid config
    /// ([`Query::validate`]); the engine's admission paths refuse
    /// invalid ones with a typed error before reaching the session, so
    /// a violation here is a programmer error and panics.
    pub fn submit(&mut self, q: Query) -> usize {
        if let Err(e) = q.validate() {
            panic!("invalid stochastic query config: {e}");
        }
        let qid = self.queries.len();
        let spec = match q {
            Query::Estimate { u, stop } => {
                let lane = self.push_lane(&u, stop, qid, Role::Single);
                Spec::Estimate { lane }
            }
            Query::Threshold { u, t } => {
                let lane = self.push_lane(&u, StopRule::Threshold(t), qid, Role::Single);
                Spec::Threshold { lane, t }
            }
            Query::Compare { u, v, t, p } => {
                let lane_u = self.push_lane(&u, StopRule::Exhaust, qid, Role::CmpU);
                let lane_v = self.push_lane(&v, StopRule::Exhaust, qid, Role::CmpV);
                Spec::Compare { lane_u, lane_v, t, p, live_u: true, live_v: true }
            }
            Query::Argmax { arms, floor } => {
                let states = arms
                    .into_iter()
                    .enumerate()
                    .map(|(k, a)| ArmState {
                        lane: self.push_lane(&a.u, a.stop, qid, Role::Arm(k)),
                        offset: a.offset,
                        scale: a.scale,
                        status: ArmStatus::Racing,
                        prev: None,
                    })
                    .collect();
                Spec::Argmax {
                    arms: states,
                    floor,
                    decided_early: false,
                    pruned_at: Vec::new(),
                    start_sweep: self.eng.sweeps(),
                }
            }
            Query::Trace { f, cfg } => self.stochastic_spec(f, cfg, qid),
            Query::LogDet { cfg } => self.stochastic_spec(SpectralFn::Log, cfg, qid),
        };
        self.queries.push(QueryState { spec, answer: None, parked: false });
        self.unresolved += 1;
        // zero-vector lanes resolve inside the engine at push; absorb them
        // and resolve the trivially-decidable cases (both-zero compares,
        // empty argmax batches) without spending a sweep. Non-trivial
        // argmax queries deliberately wait for the first sweep — pruning
        // rounds run once per sweep, exactly like the standalone race.
        self.absorb_done();
        match &self.queries[qid].spec {
            Spec::Argmax { arms, .. } => {
                if arms.is_empty() {
                    self.finish_argmax(qid, None, Vec::new(), false);
                }
            }
            Spec::Compare { .. } => self.resolve_compare(qid),
            Spec::Estimate { .. } | Spec::Threshold { .. } | Spec::Stochastic { .. } => {}
        }
        qid
    }

    /// Compile a stochastic query: derive every probe vector from the
    /// splittable stream (pure in `(seed, index)` — worker count and
    /// sweep mode cannot move a probe) and push one `Exhaust` lane per
    /// probe; all stopping is session-side, from the bracket logic in
    /// [`Session::resolve_stochastic`]. Non-inverse spectral functions
    /// record the recurrence transcript to rebuild their brackets.
    fn stochastic_spec(&mut self, f: SpectralFn, cfg: SlqConfig, qid: usize) -> Spec {
        let n = self.eng.dim();
        let record = !matches!(f, SpectralFn::Inverse);
        let m = cfg.probes;
        let mut lanes = Vec::with_capacity(m);
        let mut unorm2 = Vec::with_capacity(m);
        for i in 0..m {
            let u = probe_vector(cfg.dist, cfg.seed, i as u64, n);
            unorm2.push(u.iter().map(|x| x * x).sum::<f64>());
            lanes.push(self.push_lane_with(&u, StopRule::Exhaust, qid, Role::Probe(i), record));
        }
        Spec::Stochastic {
            f,
            cfg,
            lanes,
            unorm2,
            live: vec![true; m],
            brackets: vec![None; m],
            retired_early: 0,
            retired_at: Vec::new(),
            rounds: 0,
            stderr_trace: Vec::new(),
        }
    }

    /// Number of queries submitted so far.
    pub fn queries(&self) -> usize {
        self.queries.len()
    }

    /// True once query `qid` carries an answer.
    pub fn is_resolved(&self, qid: usize) -> bool {
        self.queries[qid].answer.is_some()
    }

    /// The answer of query `qid`, if resolved.
    pub fn answer(&self, qid: usize) -> Option<&Answer> {
        self.queries[qid].answer.as_ref()
    }

    /// Latest bounds of a single-lane (estimate or threshold) query —
    /// mid-flight snapshot while racing, final bounds after. `None` for
    /// multi-lane kinds or before the first sweep.
    pub fn bounds(&self, qid: usize) -> Option<Bounds> {
        match &self.queries[qid].spec {
            Spec::Estimate { lane } | Spec::Threshold { lane, .. } => self.latest[*lane],
            _ => None,
        }
    }

    /// Panel sweeps performed so far.
    pub fn sweeps(&self) -> usize {
        self.eng.sweeps()
    }

    /// Eviction log of the underlying engine (dominance-pruned arms and
    /// decided-query lane retirements).
    pub fn retired(&self) -> &[RetireEvent] {
        self.eng.retired()
    }

    /// Queries still without an answer.
    pub fn unresolved(&self) -> usize {
        self.unresolved
    }

    /// True while some lane is racing in the panel or waiting in the
    /// queue. Suspended lanes (parked queries) do **not** count — a
    /// session whose every unresolved query is parked reports no work.
    pub fn has_work(&self) -> bool {
        self.eng.has_work()
    }

    /// Lanes of `qid` the engine still owns (racing, queued, or
    /// suspended), ascending by lane id. Empty once the query resolved.
    fn live_lanes(&self, qid: usize) -> Vec<usize> {
        if self.queries[qid].answer.is_some() {
            return Vec::new();
        }
        match &self.queries[qid].spec {
            Spec::Estimate { lane } | Spec::Threshold { lane, .. } => vec![*lane],
            Spec::Compare { lane_u, lane_v, live_u, live_v, .. } => {
                let mut v = Vec::new();
                if *live_u {
                    v.push(*lane_u);
                }
                if *live_v {
                    v.push(*lane_v);
                }
                v
            }
            Spec::Argmax { arms, .. } => arms
                .iter()
                .filter(|a| matches!(a.status, ArmStatus::Racing))
                .map(|a| a.lane)
                .collect(),
            Spec::Stochastic { lanes, live, .. } => lanes
                .iter()
                .zip(live)
                .filter(|&(_, &alive)| alive)
                .map(|(&l, _)| l)
                .collect(),
        }
    }

    /// Panel lanes query `qid` still needs (0 once resolved): the
    /// accounting unit of the multi-operator engine's global lane budget
    /// ([`crate::quadrature::engine`]).
    pub fn lane_demand(&self, qid: usize) -> usize {
        self.live_lanes(qid).len()
    }

    /// Owner of panel lane `lane`: the owning query id, plus the probe
    /// index when the lane serves a stochastic query. The engine's
    /// flight recorder uses this to attribute lane-retirement events
    /// back to the query span (and probe) they belong to.
    pub fn lane_query(&self, lane: usize) -> Option<(usize, Option<usize>)> {
        self.lane_owner.get(lane).map(|&(qid, role)| {
            let probe = match role {
                Role::Probe(i) => Some(i),
                _ => None,
            };
            (qid, probe)
        })
    }

    /// True while `qid` is parked by [`Session::suspend_query`].
    pub fn is_parked(&self, qid: usize) -> bool {
        self.queries[qid].parked
    }

    /// Park a whole query: every lane it still owns leaves the panel via
    /// [`BlockGql::suspend`] (full mid-run state preserved) and resolution
    /// is deferred, so a parked query neither consumes sweeps nor
    /// decides. [`Session::resume_query`] re-queues the lanes in push
    /// order and the query continues **bit-identically** — per-lane op
    /// sequences are untouched (the engine's suspend contract) and the
    /// query's own resolution rounds see exactly the bracket sequence an
    /// uninterrupted run would have seen, just spread over more session
    /// steps. Returns `false` for resolved or already-parked queries.
    ///
    /// This is the [`crate::quadrature::engine`] lane-budget hook; a
    /// session with parked queries must be driven by [`Session::step`]
    /// (not [`Session::run`], which expects every query to stay live).
    pub fn suspend_query(&mut self, qid: usize) -> bool {
        if self.queries[qid].answer.is_some() || self.queries[qid].parked {
            return false;
        }
        for lane in self.live_lanes(qid) {
            let ok = self.eng.suspend(lane);
            debug_assert!(ok, "live lane {lane} of query {qid} must be suspendable");
        }
        self.queries[qid].parked = true;
        true
    }

    /// Un-park a query suspended by [`Session::suspend_query`]: its lanes
    /// re-enter the pending queue (push order preserved) and are admitted
    /// at the next panel round. Returns `false` if `qid` is not parked.
    pub fn resume_query(&mut self, qid: usize) -> bool {
        if !self.queries[qid].parked {
            return false;
        }
        for lane in self.live_lanes(qid) {
            let ok = self.eng.resume(lane);
            debug_assert!(ok, "parked lane {lane} of query {qid} must resume");
        }
        self.queries[qid].parked = false;
        true
    }

    /// True when [`Session::cancel`] would succeed right now: the query
    /// is an anytime kind — estimate or stochastic — still unresolved
    /// and holding at least one bracket to answer with. The engine's
    /// deadline shedding uses this as its readiness probe.
    pub fn can_cancel(&self, qid: usize) -> bool {
        if self.queries[qid].answer.is_some() {
            return false;
        }
        match &self.queries[qid].spec {
            Spec::Estimate { lane } => self.latest[*lane].is_some(),
            Spec::Stochastic { brackets, .. } => brackets.iter().any(Option::is_some),
            _ => false,
        }
    }

    /// Scheduler hook: resolve an **anytime** query right now with its
    /// latest snapshot, retiring its lanes. Estimates answer with their
    /// mid-flight four-bound bracket; stochastic queries answer with
    /// the combined interval over whatever probes have contributed so
    /// far (possibly short of tolerance — the report says so).
    /// Cross-operator consumers
    /// ([`crate::quadrature::engine::race_dg_joint`]) decide from
    /// mid-flight brackets and stop refining the moment the surrounding
    /// decision lands — without this the abandoned lanes would keep
    /// sweeping to exhaustion. Returns `false` for decision kinds,
    /// already-resolved queries, or a query that has not produced a
    /// bracket yet.
    pub fn cancel(&mut self, qid: usize) -> bool {
        if self.queries[qid].answer.is_some() {
            return false;
        }
        let lane = match &self.queries[qid].spec {
            Spec::Estimate { lane } => *lane,
            Spec::Stochastic { .. } => return self.cancel_stochastic(qid),
            _ => return false,
        };
        let Some(b) = self.latest[lane] else {
            return false;
        };
        if self.queries[qid].parked {
            // suspended lanes live outside the engine's retire scope;
            // re-queue them first so the eviction below can find them
            self.resume_query(qid);
        }
        let ok = self.eng.retire(lane, RetireReason::Decided);
        debug_assert!(ok, "unresolved estimate lane must be retirable");
        // no trace even when enabled: the lane's history is gone with it
        self.resolve(qid, Answer::Estimate { bounds: b, iters: b.iter, trace: None });
        true
    }

    /// The dominance safety margin currently in force: the fixed floor
    /// [`PRUNE_MARGIN`] scaled up by the worst bracket wiggle observed in
    /// *this* session so far (ROADMAP "adaptive PRUNE_MARGIN" item). The
    /// margin is monotonically non-decreasing, so pruning only gets more
    /// conservative as noise is observed; evictions taken before the
    /// first wiggle appears still used the smaller floor, so selection
    /// identity with exhaustive scoring is an empirical guarantee —
    /// property-tested in `rust/tests/prop_session.rs` — not a
    /// construction.
    pub fn prune_margin(&self) -> f64 {
        PRUNE_MARGIN.max(WIGGLE_HEADROOM * self.wiggle)
    }

    /// Aggregate session accounting.
    pub fn stats(&self) -> SessionStats {
        let mut pruned = 0;
        let mut decided_early = 0;
        for q in &self.queries {
            if let Spec::Argmax { pruned_at, decided_early: de, .. } = &q.spec {
                pruned += pruned_at.len();
                if *de {
                    decided_early += 1;
                }
            }
        }
        SessionStats {
            queries: self.queries.len(),
            lanes: self.lane_owner.len(),
            sweeps: self.eng.sweeps(),
            pruned,
            decided_early,
            prune_margin: self.prune_margin(),
        }
    }

    /// Publish the session accounting into `reg` under `session.*` names
    /// (idempotent set-style writes), plus per-resolved-query fitted
    /// contraction rates when tracing is enabled.
    pub fn export_into(&self, reg: &MetricsRegistry) {
        let st = self.stats();
        reg.set_counter("session.queries", st.queries as u64);
        reg.set_counter("session.lanes", st.lanes as u64);
        reg.set_counter("session.sweeps", st.sweeps as u64);
        reg.set_counter("session.pruned", st.pruned as u64);
        reg.set_counter("session.decided_early", st.decided_early as u64);
        reg.set_gauge("session.prune_margin", st.prune_margin);
        reg.set_gauge("session.unresolved", self.unresolved as f64);
        if self.trace_enabled {
            let mut rates = crate::metrics::Histogram::new();
            for q in &self.queries {
                if let Some(rate) = q
                    .answer
                    .as_ref()
                    .and_then(Answer::trace)
                    .and_then(GapTrace::fitted_rate)
                {
                    rates.record(rate);
                }
            }
            if rates.count() > 0 {
                reg.set_histogram("session.fitted_contraction_rate", rates);
            }
        }
        // stochastic.* block: probe accounting, variance trajectory, and
        // the round each query hit tolerance (absent for exhausted ones)
        let mut st_queries = 0u64;
        let mut st_probes = 0u64;
        let mut st_retired = 0u64;
        let mut st_tol_met = 0u64;
        let mut stderrs = crate::metrics::Histogram::new();
        let mut hit_rounds = crate::metrics::Histogram::new();
        for q in &self.queries {
            let Spec::Stochastic { cfg, retired_early, stderr_trace, .. } = &q.spec else {
                continue;
            };
            st_queries += 1;
            st_probes += cfg.probes as u64;
            st_retired += *retired_early as u64;
            for &s in stderr_trace {
                stderrs.record(s);
            }
            if let Some(r) = q.answer.as_ref().and_then(Answer::stochastic) {
                if r.tol_met {
                    st_tol_met += 1;
                }
                if let Some(round) = r.hit_round {
                    hit_rounds.record(round as f64);
                }
            }
        }
        if st_queries > 0 {
            reg.set_counter("stochastic.queries", st_queries);
            reg.set_counter("stochastic.probes_issued", st_probes);
            reg.set_counter("stochastic.probes_retired", st_retired);
            reg.set_counter("stochastic.tol_met", st_tol_met);
            if stderrs.count() > 0 {
                reg.set_histogram("stochastic.stderr", stderrs);
            }
            if hit_rounds.count() > 0 {
                reg.set_histogram("stochastic.hit_round", hit_rounds);
            }
        }
    }

    /// One scheduler round against `op` (the operator this session was
    /// constructed for): a panel sweep plus a resolution pass. Returns
    /// `false` (without sweeping) once the engine has no lane or pending
    /// query left — resolution still runs, so immediately-decidable
    /// queries answer even then.
    pub fn step(&mut self, op: &dyn SymOp) -> bool {
        let progressed = self.eng.step_panel(op);
        self.absorb_done();
        self.refresh_active();
        self.resolve_round();
        progressed
    }

    /// Drive every query to its answer; answers in submission order.
    pub fn run(&mut self, op: &dyn SymOp) -> Vec<Answer> {
        while self.unresolved > 0 {
            if !self.step(op) {
                break;
            }
        }
        debug_assert_eq!(self.unresolved, 0, "engine drained with unresolved queries");
        self.queries
            .iter()
            .map(|q| q.answer.clone().expect("resolved"))
            .collect()
    }

    fn resolve(&mut self, qid: usize, ans: Answer) {
        let q = &mut self.queries[qid];
        if q.answer.is_none() {
            q.answer = Some(ans);
            self.unresolved -= 1;
        }
    }

    /// Route finished lanes to their queries.
    fn absorb_done(&mut self) {
        for r in self.eng.take_done() {
            let (qid, role) = self.lane_owner[r.id];
            self.latest[r.id] = Some(r.bounds);
            let mut answered: Option<Answer> = None;
            match (&mut self.queries[qid].spec, role) {
                (Spec::Estimate { .. }, Role::Single) => {
                    let trace = if self.trace_enabled && !r.history.is_empty() {
                        Some(Box::new(GapTrace::from_history(&r.history)))
                    } else {
                        None
                    };
                    answered =
                        Some(Answer::Estimate { bounds: r.bounds, iters: r.iters, trace });
                }
                (Spec::Threshold { t, .. }, Role::Single) => {
                    let t = *t;
                    let decision = r.decision.unwrap_or(t < r.bounds.mid());
                    let stats =
                        JudgeStats { iters: r.iters, outcome: threshold_outcome(&r.bounds, t) };
                    answered = Some(Answer::Threshold { decision, stats });
                }
                (Spec::Compare { live_u, .. }, Role::CmpU) => *live_u = false,
                (Spec::Compare { live_v, .. }, Role::CmpV) => *live_v = false,
                (Spec::Argmax { arms, .. }, Role::Arm(k)) => {
                    let arm = &mut arms[k];
                    // an arm pruned in the round it finished stays pruned
                    if matches!(arm.status, ArmStatus::Racing) {
                        let (lo, hi) = value_bracket(arm.offset, arm.scale, &r.bounds);
                        let est = value_estimate(arm.offset, arm.scale, &r.bounds);
                        arm.status = ArmStatus::Done { est, lo, hi, iters: r.iters };
                    }
                }
                (Spec::Stochastic { f, live, brackets, unorm2, .. }, Role::Probe(k)) => {
                    // finished (exhausted) probe: final bracket from the
                    // lane's own bounds or its recorded transcript
                    let br = match *f {
                        SpectralFn::Inverse => Some(bracket_from_bounds(&r.bounds)),
                        other => bracket_from_transcript(
                            other,
                            &r.jacobi,
                            unorm2[k],
                            self.opts.lam_min,
                            self.opts.lam_max,
                            r.bounds.exact,
                        ),
                    };
                    live[k] = false;
                    if br.is_some() {
                        brackets[k] = br;
                    }
                }
                _ => unreachable!("lane role inconsistent with its query kind"),
            }
            if let Some(ans) = answered {
                self.resolve(qid, ans);
            }
        }
    }

    /// Pull mid-flight bound snapshots out of the panel.
    fn refresh_active(&mut self) {
        let snap: Vec<(usize, Option<Bounds>)> = self.eng.active().collect();
        for (id, b) in snap {
            if b.is_some() {
                self.latest[id] = b;
            }
        }
    }

    /// Apply each unresolved multi-lane query's bound logic. Parked
    /// queries are skipped: their brackets cannot have moved, and deciding
    /// one would try to retire suspended lanes the engine no longer owns.
    fn resolve_round(&mut self) {
        for qid in 0..self.queries.len() {
            if self.queries[qid].answer.is_some() || self.queries[qid].parked {
                continue;
            }
            match self.queries[qid].spec {
                Spec::Compare { .. } => self.resolve_compare(qid),
                Spec::Argmax { .. } => self.resolve_argmax(qid),
                Spec::Stochastic { .. } => self.resolve_stochastic(qid),
                // single lanes resolve through absorb_done
                Spec::Estimate { .. } | Spec::Threshold { .. } => {}
            }
        }
    }

    /// Compare resolution: the shared ratio-verdict ladder over the two
    /// lanes' current brackets; decided queries retire both lanes.
    fn resolve_compare(&mut self, qid: usize) {
        let (lane_u, lane_v, t, p, was_live_u, was_live_v) = match &self.queries[qid].spec {
            Spec::Compare { lane_u, lane_v, t, p, live_u, live_v } => {
                (*lane_u, *lane_v, *t, *p, *live_u, *live_v)
            }
            _ => unreachable!("resolve_compare on a non-compare query"),
        };
        let (Some(bu), Some(bv)) = (self.latest[lane_u], self.latest[lane_v]) else {
            return; // a side has not produced a bracket yet
        };
        if let Some((decision, stats)) = ratio_verdict(&bu, &bv, t, p, self.max_iters) {
            if was_live_u {
                self.eng.retire(lane_u, RetireReason::Decided);
            }
            if was_live_v {
                self.eng.retire(lane_v, RetireReason::Decided);
            }
            if let Spec::Compare { live_u, live_v, .. } = &mut self.queries[qid].spec {
                *live_u = false;
                *live_v = false;
            }
            self.resolve(qid, Answer::Compare { decision, stats });
        }
    }

    /// Stochastic resolution round: refresh each live probe's
    /// deterministic bracket (from its lane bounds for `f = 1/x`, from
    /// its recorded transcript otherwise), retire probes whose own
    /// bracket is tight enough that further Lanczos iterations cannot
    /// help, then fold every bracket into the two-interval summary and
    /// retire the whole query once the combined interval meets the
    /// tolerance with all probes contributing — or once no lane is left
    /// to refine (exhaustion: the answer reports `tol_met` as observed).
    fn resolve_stochastic(&mut self, qid: usize) {
        let (f, cfg, lanes) = match &self.queries[qid].spec {
            Spec::Stochastic { f, cfg, lanes, .. } => (*f, *cfg, lanes.clone()),
            _ => unreachable!("resolve_stochastic on a non-stochastic query"),
        };
        // --- phase 1: fresh brackets for live probes ---
        let mut refreshed: Vec<Option<ProbeBracket>> = Vec::with_capacity(lanes.len());
        {
            let (live, unorm2) = match &self.queries[qid].spec {
                Spec::Stochastic { live, unorm2, .. } => (live, unorm2),
                _ => unreachable!("checked above"),
            };
            for (k, &lane) in lanes.iter().enumerate() {
                if !live[k] {
                    // finished/retired probes keep their absorbed bracket
                    refreshed.push(None);
                    continue;
                }
                let br = match f {
                    SpectralFn::Inverse => self.latest[lane].map(|b| bracket_from_bounds(&b)),
                    other => {
                        let exact = self.latest[lane].is_some_and(|b| b.exact);
                        self.eng.lane_jacobi(lane).and_then(|jac| {
                            bracket_from_transcript(
                                other,
                                jac,
                                unorm2[k],
                                self.opts.lam_min,
                                self.opts.lam_max,
                                exact,
                            )
                        })
                    }
                };
                refreshed.push(br);
            }
        }
        // --- phase 2: store brackets, mark converged probes ---
        let mut to_retire: Vec<usize> = Vec::new();
        {
            let latest = &self.latest;
            let Spec::Stochastic { live, brackets, retired_early, retired_at, rounds, .. } =
                &mut self.queries[qid].spec
            else {
                unreachable!("checked above")
            };
            *rounds += 1;
            for (k, br) in refreshed.into_iter().enumerate() {
                let Some(b) = br else { continue };
                brackets[k] = Some(b);
                if live[k] && probe_converged(&b, cfg.tol) {
                    live[k] = false;
                    *retired_early += 1;
                    retired_at.push((k, latest[lanes[k]].map_or(0, |lb| lb.iter)));
                    to_retire.push(lanes[k]);
                }
            }
        }
        for lane in to_retire {
            let ok = self.eng.retire(lane, RetireReason::Decided);
            debug_assert!(ok, "converged probe lane must be retirable");
        }
        // --- phase 3: summarize and decide ---
        let (any_live, summary) = {
            let Spec::Stochastic { live, brackets, stderr_trace, .. } =
                &mut self.queries[qid].spec
            else {
                unreachable!("checked above")
            };
            let got: Vec<ProbeBracket> = brackets.iter().filter_map(|b| *b).collect();
            let summary = summarize(&got, cfg.tol);
            if let Some(s) = &summary {
                stderr_trace.push(s.stderr);
            }
            (live.iter().any(|&l| l), summary)
        };
        let Some(s) = summary else { return };
        if (s.probes == cfg.probes && s.tol_met) || !any_live {
            self.finish_stochastic(qid, s);
        }
    }

    /// Anytime exit for a stochastic query: answer from the brackets
    /// already absorbed (no fresh sweep, no bracket refresh — the stored
    /// snapshots are current as of the last resolution round). Returns
    /// `false` when no probe has contributed yet.
    fn cancel_stochastic(&mut self, qid: usize) -> bool {
        let summary = match &self.queries[qid].spec {
            Spec::Stochastic { cfg, brackets, .. } => {
                let got: Vec<ProbeBracket> = brackets.iter().filter_map(|b| *b).collect();
                summarize(&got, cfg.tol)
            }
            _ => unreachable!("cancel_stochastic on a non-stochastic query"),
        };
        let Some(s) = summary else {
            return false;
        };
        if self.queries[qid].parked {
            // suspended lanes live outside the engine's retire scope;
            // re-queue them first so the retirements below can find them
            self.resume_query(qid);
        }
        self.finish_stochastic(qid, s);
        true
    }

    /// Retire every lane the query still owns and resolve it with the
    /// report built from summary `s`.
    fn finish_stochastic(&mut self, qid: usize, s: SlqSummary) {
        for lane in self.live_lanes(qid) {
            let ok = self.eng.retire(lane, RetireReason::Decided);
            debug_assert!(ok, "live stochastic lane must be retirable");
        }
        let (f, cfg, lanes, retired_early, retired_at, rounds) =
            match &mut self.queries[qid].spec {
                Spec::Stochastic {
                    f, cfg, lanes, live, retired_early, retired_at, rounds, ..
                } => {
                    for l in live.iter_mut() {
                        *l = false;
                    }
                    (*f, *cfg, lanes.clone(), *retired_early, retired_at.clone(), *rounds)
                }
                _ => unreachable!("finish_stochastic on a non-stochastic query"),
            };
        let iters: usize =
            lanes.iter().map(|&l| self.latest[l].map_or(0, |b| b.iter)).sum();
        let hit_round = (s.tol_met && s.probes == cfg.probes).then_some(rounds);
        let report = StochasticReport {
            f,
            estimate: s.estimate,
            envelope: s.envelope,
            combined: s.combined,
            stderr: s.stderr,
            probes_issued: cfg.probes,
            probes_contributing: s.probes,
            probes_retired_early: retired_early,
            retired_at,
            tol: cfg.tol,
            tol_met: s.tol_met,
            hit_round,
            rounds,
            iters,
        };
        self.resolve(qid, Answer::Stochastic(Box::new(report)));
    }

    /// Argmax resolution: dominance pruning (under [`RacePolicy::Prune`])
    /// plus the exhaustive scoring exit once every arm is done.
    fn resolve_argmax(&mut self, qid: usize) {
        let policy = self.policy;
        // --- phase 1: snapshot brackets, update wiggle and prev ---
        let mut wiggle = self.wiggle;
        let (m, floor, brackets, ests, mut racing, mut pruned, lanes) = {
            let latest = &self.latest;
            let (arms, floor) = match &mut self.queries[qid].spec {
                Spec::Argmax { arms, floor, .. } => (arms, *floor),
                _ => unreachable!("resolve_argmax on a non-argmax query"),
            };
            let m = arms.len();
            let mut brackets: Vec<Option<(f64, f64, usize)>> = Vec::with_capacity(m);
            let mut ests: Vec<Option<f64>> = Vec::with_capacity(m);
            let mut racing: Vec<bool> = Vec::with_capacity(m);
            let mut pruned: Vec<bool> = Vec::with_capacity(m);
            let mut lanes: Vec<usize> = Vec::with_capacity(m);
            for arm in arms.iter_mut() {
                let br = match arm.status {
                    ArmStatus::Done { lo, hi, iters, .. } => Some((lo, hi, iters)),
                    ArmStatus::Racing => latest[arm.lane].map(|b| {
                        let (lo, hi) = value_bracket(arm.offset, arm.scale, &b);
                        (lo, hi, b.iter)
                    }),
                    ArmStatus::Pruned => None,
                };
                if let (Some((lo, hi, _)), Some((plo, phi))) = (br, arm.prev) {
                    // nesting violation = floating-point wiggle; widen the
                    // dominance margin to cover the worst seen
                    let denom = 1.0 + lo.abs() + hi.abs() + plo.abs() + phi.abs();
                    let w = (plo - lo).max(hi - phi) / denom;
                    if w > wiggle {
                        wiggle = w;
                    }
                }
                if let Some((lo, hi, _)) = br {
                    arm.prev = Some((lo, hi));
                }
                brackets.push(br);
                ests.push(match arm.status {
                    ArmStatus::Done { est, .. } => Some(est),
                    _ => None,
                });
                racing.push(matches!(arm.status, ArmStatus::Racing));
                pruned.push(matches!(arm.status, ArmStatus::Pruned));
                lanes.push(arm.lane);
            }
            (m, floor, brackets, ests, racing, pruned, lanes)
        };
        self.wiggle = wiggle;
        let margin = self.prune_margin();

        if policy == RacePolicy::Prune {
            // --- phase 2: dominance round ---
            let mut best_lo = f64::NEG_INFINITY;
            for i in 0..m {
                if !pruned[i] {
                    if let Some((lo, _, _)) = brackets[i] {
                        best_lo = best_lo.max(lo);
                    }
                }
            }
            let thresh = match floor {
                Some(f) => best_lo.max(f),
                None => best_lo,
            };
            let mut newly: Vec<(usize, usize)> = Vec::new();
            if thresh.is_finite() {
                for i in 0..m {
                    if pruned[i] {
                        continue;
                    }
                    if let Some((_, hi, iter)) = brackets[i] {
                        if dominated(hi, thresh, margin) {
                            newly.push((i, iter));
                        }
                    }
                }
            }
            if !newly.is_empty() {
                for &(i, _) in &newly {
                    if racing[i] {
                        self.eng.retire(lanes[i], RetireReason::Dominated);
                    }
                    // (finished arms have nothing to evict, but marking
                    // them keeps the survivor count honest)
                    pruned[i] = true;
                    racing[i] = false;
                }
                if let Spec::Argmax { arms, pruned_at, .. } = &mut self.queries[qid].spec {
                    for &(i, iter) in &newly {
                        arms[i].status = ArmStatus::Pruned;
                        pruned_at.push((i, iter));
                    }
                }
            }

            // --- phase 3: early exit on a decided race ---
            let survivors: Vec<usize> = (0..m).filter(|&i| !pruned[i]).collect();
            if survivors.is_empty() {
                // the floor dominated everything: no arm is feasible
                self.finish_argmax(qid, None, vec![None; m], false);
                return;
            }
            if survivors.len() == 1 {
                let w = survivors[0];
                let floor_beaten = match floor {
                    None => true,
                    Some(f) => brackets[w].map_or(false, |(lo, _, _)| dominated(f, lo, margin)),
                };
                // a racing winner is only crowned once it carries a
                // bracket — a lone survivor still waiting in the queue
                // (possible in mixed sessions) runs a sweep first, so the
                // answer always holds a usable estimate
                if floor_beaten && (!racing[w] || brackets[w].is_some()) {
                    let mut estimates: Vec<Option<f64>> = vec![None; m];
                    estimates[w] =
                        ests[w].or_else(|| brackets[w].map(|(lo, hi, _)| 0.5 * (lo + hi)));
                    if racing[w] {
                        // stop refining: the decision is determined before
                        // the winner reached its own stop rule
                        self.eng.retire(lanes[w], RetireReason::Decided);
                        self.finish_argmax(qid, Some(w), estimates, true);
                    } else {
                        self.finish_argmax(qid, Some(w), estimates, false);
                    }
                    return;
                }
                // lone survivor but the floor still straddles its bracket:
                // keep refining until its own stop rule resolves the
                // comparison exactly like the exhaustive path
            }
        }

        // --- phase 4: exhaustive scoring once every arm is done ---
        if racing.iter().any(|&r| r) {
            return;
        }
        let mut best: Option<(usize, f64)> = None;
        for i in 0..m {
            if pruned[i] {
                continue;
            }
            if let Some(est) = ests[i] {
                if best.map_or(true, |(_, g)| est > g) {
                    best = Some((i, est));
                }
            }
        }
        let winner = match (best, floor) {
            (Some((i, est)), Some(f)) if est > f => Some(i),
            (Some(_), Some(_)) => None,
            (Some((i, _)), None) => Some(i),
            (None, _) => None,
        };
        let estimates: Vec<Option<f64>> =
            (0..m).map(|i| if pruned[i] { None } else { ests[i] }).collect();
        self.finish_argmax(qid, winner, estimates, false);
    }

    /// Build the argmax answer from the query's accumulated accounting.
    fn finish_argmax(
        &mut self,
        qid: usize,
        winner: Option<usize>,
        estimates: Vec<Option<f64>>,
        crowned_early: bool,
    ) {
        let sweeps = self.eng.sweeps();
        let stats = match &mut self.queries[qid].spec {
            Spec::Argmax { arms, pruned_at, decided_early, start_sweep, .. } => {
                if crowned_early {
                    *decided_early = true;
                }
                RaceStats {
                    sweeps: sweeps - *start_sweep,
                    arms: arms.len(),
                    pruned_at: pruned_at.clone(),
                    decided_early: *decided_early,
                }
            }
            _ => unreachable!("finish_argmax on a non-argmax query"),
        };
        self.resolve(qid, Answer::Argmax { winner, estimates, stats });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::linalg::Cholesky;
    use crate::quadrature::block::run_scalar;
    use crate::quadrature::judge::{judge_ratio, judge_threshold_src, BoundSource};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn estimate_query_is_bit_identical_to_run_scalar() {
        forall(10, 0x5E5501, |rng| {
            let n = 6 + rng.below(20);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let u = randvec(rng, n);
            let reference = run_scalar(&a, &u, opts, StopRule::GapRel(1e-8), false);
            let mut s = Session::new(&a, opts, 1, RacePolicy::Prune);
            let qid = s.submit(Query::Estimate { u, stop: StopRule::GapRel(1e-8) });
            match &s.run(&a)[qid] {
                Answer::Estimate { bounds, iters, .. } => {
                    assert_eq!(*iters, reference.iters);
                    assert_eq!(bounds.gauss.to_bits(), reference.bounds.gauss.to_bits());
                    assert_eq!(
                        bounds.radau_upper.to_bits(),
                        reference.bounds.radau_upper.to_bits()
                    );
                }
                other => panic!("wrong answer kind {other:?}"),
            }
        });
    }

    #[test]
    fn traced_session_is_bit_identical_and_carries_a_gap_trace() {
        let mut rng = Rng::new(0x5E5509);
        let n = 24;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let u = randvec(&mut rng, n);

        let mut plain = Session::new(&a, opts, 1, RacePolicy::Prune);
        let p = plain.submit(Query::Estimate { u: u.clone(), stop: StopRule::GapRel(1e-8) });
        let plain_ans = plain.run(&a);

        let mut traced =
            Session::new(&a, opts, 1, RacePolicy::Prune).record_traces(true);
        let t = traced.submit(Query::Estimate { u, stop: StopRule::GapRel(1e-8) });
        let traced_ans = traced.run(&a);

        // tracing must not perturb the arithmetic
        let (pb, tb) = match (&plain_ans[p], &traced_ans[t]) {
            (
                Answer::Estimate { bounds: pb, trace: none, .. },
                Answer::Estimate { bounds: tb, .. },
            ) => {
                assert!(none.is_none(), "untraced session must not record");
                (*pb, *tb)
            }
            other => panic!("wrong answer kinds {other:?}"),
        };
        assert_eq!(pb.gauss.to_bits(), tb.gauss.to_bits());
        assert_eq!(pb.radau_upper.to_bits(), tb.radau_upper.to_bits());

        let trace = traced_ans[t].trace().expect("traced answer carries a trace");
        assert!(trace.len() >= 3, "expected a multi-point trace, got {}", trace.len());
        let rate = trace.fitted_rate().expect("fit succeeds on a real trajectory");
        assert!(rate > 0.0 && rate < 1.0, "contraction rate {rate} not in (0, 1)");

        let reg = MetricsRegistry::new();
        traced.export_into(&reg);
        let snap = reg.snapshot();
        assert!(snap.get("session.queries").is_some());
        assert!(
            snap.get("session.fitted_contraction_rate").is_some(),
            "traced session export publishes the rate histogram"
        );
    }

    #[test]
    fn threshold_query_matches_scalar_judge_exactly() {
        forall(10, 0x5E5502, |rng| {
            let n = 6 + rng.below(20);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let u = randvec(rng, n);
            let exact = Cholesky::factor(&a.to_dense()).unwrap().bif(&u);
            for factor in [0.5, 0.9, 1.1, 2.0] {
                let t = exact * factor;
                let (want, want_stats) = judge_threshold_src(&a, &u, t, opts, BoundSource::Radau);
                let mut s = Session::new(&a, opts, 1, RacePolicy::Prune);
                let qid = s.submit(Query::Threshold { u: u.clone(), t });
                match &s.run(&a)[qid] {
                    Answer::Threshold { decision, stats } => {
                        assert_eq!(*decision, want, "factor {factor}");
                        assert_eq!(stats.iters, want_stats.iters, "factor {factor}");
                        assert_eq!(stats.outcome, want_stats.outcome, "factor {factor}");
                    }
                    other => panic!("wrong answer kind {other:?}"),
                }
            }
        });
    }

    #[test]
    fn compare_query_matches_exact_comparison() {
        forall(10, 0x5E5503, |rng| {
            let n = 6 + rng.below(16);
            let (a, w) = random_sparse_spd(rng, n, 0.4, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let (u, v) = (randvec(rng, n), randvec(rng, n));
            let ch = Cholesky::factor(&a.to_dense()).unwrap();
            let (eu, ev) = (ch.bif(&u), ch.bif(&v));
            for p in [0.2, 0.5, 0.8] {
                let truth = p * ev - eu;
                for t in [truth - 0.5, truth + 0.5] {
                    let mut s = Session::new(&a, opts, 2, RacePolicy::Prune);
                    let qid = s.submit(Query::Compare { u: u.clone(), v: v.clone(), t, p });
                    assert_eq!(
                        s.run(&a)[qid].decision(),
                        Some(t < truth),
                        "p={p} t={t} truth={truth}"
                    );
                }
            }
        });
    }

    #[test]
    fn mixed_session_answers_match_the_sequential_paths() {
        forall(8, 0x5E5504, |rng| {
            let n = 10 + rng.below(20);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let ch = Cholesky::factor(&a.to_dense()).unwrap();
            let ut = randvec(rng, n);
            let (cu, cv) = (randvec(rng, n), randvec(rng, n));
            let arms: Vec<Vec<f64>> = (0..4).map(|_| randvec(rng, n)).collect();
            let t_thresh = ch.bif(&ut) * (0.5 + rng.f64());
            let truth_cmp = 0.5 * ch.bif(&cv) - ch.bif(&cu);
            let t_cmp = truth_cmp + if rng.bool(0.5) { 0.3 } else { -0.3 };
            let want_thresh = t_thresh < ch.bif(&ut);
            let want_cmp = t_cmp < truth_cmp;
            let want_winner = arms
                .iter()
                .enumerate()
                .map(|(i, u)| (i, ch.bif(u)))
                .fold(None::<(usize, f64)>, |best, (i, v)| {
                    if best.map_or(true, |(_, g)| v > g) {
                        Some((i, v))
                    } else {
                        best
                    }
                })
                .map(|(i, _)| i);

            let width = 1 + rng.below(7);
            let mut s = Session::new(&a, opts, width, RacePolicy::Prune);
            let q1 = s.submit(Query::Threshold { u: ut, t: t_thresh });
            let q2 = s.submit(Query::Compare { u: cu, v: cv, t: t_cmp, p: 0.5 });
            let q3 = s.submit(Query::Argmax {
                arms: arms
                    .into_iter()
                    .map(|u| QueryArm { u, stop: StopRule::GapRel(1e-10), offset: 0.0, scale: 1.0 })
                    .collect(),
                floor: None,
            });
            let answers = s.run(&a);
            assert_eq!(answers[q1].decision(), Some(want_thresh));
            assert_eq!(answers[q2].decision(), Some(want_cmp));
            assert_eq!(answers[q3].winner(), Some(want_winner));
            let st = s.stats();
            assert_eq!(st.queries, 3);
            assert!(st.sweeps > 0);
        });
    }

    #[test]
    fn zero_vector_queries_resolve_without_sweeps() {
        let mut rng = Rng::new(0x5E5505);
        let (a, w) = random_sparse_spd(&mut rng, 8, 0.4, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let z = vec![0.0; 8];
        let mut s = Session::new(&a, opts, 2, RacePolicy::Prune);
        let q1 = s.submit(Query::Threshold { u: z.clone(), t: -1.0 });
        let q2 = s.submit(Query::Compare { u: z.clone(), v: z, t: 0.5, p: 0.3 });
        let q3 = s.submit(Query::Argmax { arms: Vec::new(), floor: Some(0.0) });
        assert!(s.is_resolved(q1) && s.is_resolved(q2) && s.is_resolved(q3));
        let answers = s.run(&a);
        assert_eq!(s.sweeps(), 0);
        assert_eq!(answers[q1].decision(), Some(true), "-1 < 0 exactly");
        assert_eq!(answers[q2].decision(), Some(false), "0.5 < 0 is false");
        assert_eq!(answers[q3].winner(), Some(None));
    }

    #[test]
    fn compare_one_zero_side_matches_scalar_ratio_judge() {
        let mut rng = Rng::new(0x5E5506);
        let n = 14;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.4, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let u = randvec(&mut rng, n);
        let z = vec![0.0; n];
        let exact = Cholesky::factor(&a.to_dense()).unwrap().bif(&u);
        // v = 0 ⇒ truth = −BIF_u; u = 0 ⇒ truth = p·BIF_v
        for (uu, vv, t, p) in [
            (u.clone(), z.clone(), -exact * 0.5, 0.7),
            (z.clone(), u.clone(), exact * 0.5, 0.7),
        ] {
            let (want, _) = judge_ratio(&a, &uu, &vv, t, p, opts);
            let mut s = Session::new(&a, opts, 2, RacePolicy::Prune);
            let qid = s.submit(Query::Compare { u: uu, v: vv, t, p });
            assert_eq!(s.run(&a)[qid].decision(), Some(want));
        }
    }

    #[test]
    fn session_sharing_saves_sweeps_over_sequential_sessions() {
        // the point of the redesign: co-scheduled queries share panel
        // sweeps, so a mixed session spends fewer traversals than the sum
        // of per-query runs
        let mut rng = Rng::new(0x5E5507);
        let n = 40;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.15, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let queries: Vec<Query> = (0..6)
            .map(|_| Query::Estimate {
                u: randvec(&mut rng, n),
                stop: StopRule::GapRel(1e-8),
            })
            .collect();
        let sequential: usize = queries
            .iter()
            .map(|q| {
                let mut s = Session::new(&a, opts, 8, RacePolicy::Prune);
                s.submit(q.clone());
                s.run(&a);
                s.sweeps()
            })
            .sum();
        let mut s = Session::new(&a, opts, 8, RacePolicy::Prune);
        for q in queries {
            s.submit(q);
        }
        s.run(&a);
        assert!(
            s.sweeps() < sequential,
            "shared panel must save sweeps ({} vs {sequential})",
            s.sweeps()
        );
    }

    #[test]
    fn adaptive_margin_never_drops_below_the_fixed_floor() {
        let mut rng = Rng::new(0x5E5508);
        let n = 24;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut s = Session::new(&a, opts, 4, RacePolicy::Prune);
        assert_eq!(s.prune_margin(), PRUNE_MARGIN, "fresh session sits at the floor");
        let arms = (0..5)
            .map(|_| QueryArm {
                u: randvec(&mut rng, n),
                stop: StopRule::GapRel(1e-10),
                offset: 1.0,
                scale: -1.0,
            })
            .collect();
        s.submit(Query::Argmax { arms, floor: None });
        s.run(&a);
        assert!(s.prune_margin() >= PRUNE_MARGIN);
        assert_eq!(s.stats().prune_margin, s.prune_margin());
    }

    /// Diagonal operator: a Rademacher probe `u` has `u_i^2 = 1`, so every
    /// probe evaluates `u^T f(A) u = sum_i f(d_i)` — the exact spectral
    /// sum with **zero** Monte-Carlo variance. The combined interval
    /// therefore degenerates to the quadrature envelope and must contain
    /// the exact value deterministically.
    #[test]
    fn stochastic_trace_on_a_diagonal_operator_is_exact() {
        let d = [0.6, 1.1, 1.7, 2.4, 3.0, 3.9, 4.7, 5.5, 6.2, 7.0];
        let mut b = crate::sparse::CsrBuilder::new(d.len());
        for (i, &di) in d.iter().enumerate() {
            b.push(i, i, di);
        }
        let a = b.build();
        let opts = GqlOptions::new(0.5, 7.2);
        let cases: [(Query, f64); 3] = [
            (
                Query::Trace {
                    f: SpectralFn::Inverse,
                    cfg: SlqConfig::new(6, 0x51D1, 1e-6),
                },
                d.iter().map(|&x| 1.0 / x).sum(),
            ),
            (
                Query::LogDet { cfg: SlqConfig::new(6, 0x51D2, 1e-6) },
                d.iter().map(|&x| x.ln()).sum(),
            ),
            (
                Query::Trace {
                    f: SpectralFn::Exp,
                    cfg: SlqConfig::new(6, 0x51D3, 1e-6),
                },
                d.iter().map(|&x| x.exp()).sum(),
            ),
        ];
        for (q, exact) in cases {
            let mut s = Session::new(&a, opts, 4, RacePolicy::Prune);
            let qid = s.submit(q);
            let ans = s.run(&a);
            let r = ans[qid].stochastic().expect("stochastic answer kind");
            let slack = 1e-9 * (1.0 + exact.abs());
            assert!(
                r.combined.lo - slack <= exact && exact <= r.combined.hi + slack,
                "{}: exact {exact} outside [{}, {}]",
                r.f,
                r.combined.lo,
                r.combined.hi
            );
            assert!(r.tol_met, "{}: zero-variance probes must hit tolerance", r.f);
            assert_eq!(r.probes_contributing, 6);
            assert!(
                r.stderr <= 1e-7 * (1.0 + exact.abs()),
                "{}: identical probe values must have ~zero spread, got {}",
                r.f,
                r.stderr
            );
            assert_eq!(r.hit_round, Some(r.rounds));
        }
    }

    /// Sparse SPD instances: the exact trace/logdet must sit inside the
    /// combined interval widened by a 4x guard band. The t-interval alone
    /// is a 95% statement; the quadrature envelope plus the 4x factor
    /// pushes coverage far enough that the pinned-seed runs here are
    /// reliable, while still catching any systematic bias or a broken
    /// bracket orientation outright.
    #[test]
    fn stochastic_intervals_cover_exact_trace_and_logdet() {
        forall(5, 0x5E550A, |rng| {
            let n = 14 + rng.below(10);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let ch = Cholesky::factor(&a.to_dense()).unwrap();
            let exact_logdet = ch.logdet();
            let exact_trinv: f64 = (0..n)
                .map(|i| {
                    let mut e = vec![0.0; n];
                    e[i] = 1.0;
                    ch.bif(&e)
                })
                .sum();
            let seed = rng.next_u64();
            let cfg = SlqConfig::new(16, seed, 2e-2);
            let mut s = Session::new(&a, opts, 8, RacePolicy::Prune);
            let qt = s.submit(Query::Trace { f: SpectralFn::Inverse, cfg });
            let ql = s.submit(Query::LogDet { cfg });
            let ans = s.run(&a);
            for (qid, exact) in [(qt, exact_trinv), (ql, exact_logdet)] {
                let r = ans[qid].stochastic().expect("stochastic answer kind");
                let guard = 4.0 * (r.combined.width() / 2.0) + 1e-9;
                assert!(
                    (exact - r.combined.mid()).abs() <= guard,
                    "{}: exact {exact} vs interval [{}, {}] (n={n})",
                    r.f,
                    r.combined.lo,
                    r.combined.hi
                );
                // structural invariants of the two-interval report
                assert!(r.combined.lo <= r.envelope.lo && r.envelope.hi <= r.combined.hi);
                assert!(r.combined.contains(r.estimate));
                assert_eq!(r.probes_issued, 16);
                assert!(r.probes_contributing == 16 && r.iters > 0);
            }
            // pinned seed => bit-identical reruns
            let mut s2 = Session::new(&a, opts, 8, RacePolicy::Prune);
            let qt2 = s2.submit(Query::Trace { f: SpectralFn::Inverse, cfg });
            let ans2 = s2.run(&a);
            let (r1, r2) = (
                ans[qt].stochastic().unwrap(),
                ans2[qt2].stochastic().unwrap(),
            );
            assert_eq!(r1.estimate.to_bits(), r2.estimate.to_bits());
            assert_eq!(r1.combined.lo.to_bits(), r2.combined.lo.to_bits());
            assert_eq!(r1.iters, r2.iters);
        });
    }

    /// The anytime contract: before any sweep a stochastic query has no
    /// bracket and refuses to cancel; after a few panel rounds it cancels
    /// with a valid (if tolerance-short) interval, and its lanes leave
    /// the engine.
    #[test]
    fn stochastic_cancel_mid_flight_carries_a_valid_interval() {
        let mut rng = Rng::new(0x5E550B);
        let n = 28;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut s = Session::new(&a, opts, 4, RacePolicy::Prune);
        let qid = s.submit(Query::Trace {
            f: SpectralFn::Inverse,
            cfg: SlqConfig::new(8, 0xFEED, 1e-12),
        });
        assert!(!s.can_cancel(qid), "no bracket before the first sweep");
        assert!(!s.cancel(qid));
        for _ in 0..3 {
            assert!(s.step(&a));
        }
        assert!(s.can_cancel(qid));
        assert!(s.cancel(qid));
        let r = s.answer(qid).unwrap().stochastic().expect("stochastic answer");
        assert!(r.probes_contributing >= 1);
        assert!(r.combined.lo <= r.estimate && r.estimate <= r.combined.hi);
        assert!(r.combined.lo.is_finite() && r.combined.hi.is_finite());
        assert_eq!(s.lane_demand(qid), 0, "cancel retires every probe lane");
        assert!(!s.can_cancel(qid), "resolved queries are not cancellable");
    }

    #[test]
    fn stochastic_queries_coalesce_with_bilinear_queries_on_one_panel() {
        let mut rng = Rng::new(0x5E550C);
        let n = 20;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let u = randvec(&mut rng, n);
        let mut s = Session::new(&a, opts, 8, RacePolicy::Prune);
        let qe = s.submit(Query::Estimate { u, stop: StopRule::GapRel(1e-8) });
        let ql = s.submit(Query::LogDet { cfg: SlqConfig::new(4, 0xC0A1, 5e-2) });
        let ans = s.run(&a);
        assert!(matches!(ans[qe], Answer::Estimate { .. }));
        let r = ans[ql].stochastic().expect("stochastic answer");
        let ch = Cholesky::factor(&a.to_dense()).unwrap();
        let guard = 4.0 * (r.combined.width() / 2.0) + 1e-9;
        assert!((ch.logdet() - r.combined.mid()).abs() <= guard);
        let reg = MetricsRegistry::new();
        s.export_into(&reg);
        let snap = reg.snapshot();
        assert!(snap.get("stochastic.queries").is_some());
        assert!(snap.get("stochastic.probes_issued").is_some());
    }
}
