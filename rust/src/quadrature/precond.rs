//! Jacobi preconditioning (paper §5.4): `u^T A^{-1} u =
//! (Cu)^T (C A C^T)^{-1} (Cu)` for any nonsingular `C`; with
//! `C = diag(A)^{-1/2}` the transformed matrix has unit diagonal and
//! (often) a much smaller condition number, which Thm. 3/5/8 translate
//! directly into fewer quadrature iterations.  Ablated in
//! `bench_ablation`.

use crate::sparse::SymOp;

/// The operator `D^{-1/2} A D^{-1/2}` (never materialized).
pub struct JacobiPrecond<'a> {
    op: &'a dyn SymOp,
    /// d_scale[i] = 1/sqrt(diag[i])
    d_scale: Vec<f64>,
    /// scratch for the inner matvec; a `Mutex` (not `RefCell`) so the
    /// wrapper satisfies `SymOp: Sync` — uncontended in every current
    /// caller, so the lock is a dozen nanoseconds against an O(nnz) matvec
    scratch: std::sync::Mutex<(Vec<f64>, Vec<f64>)>,
}

impl<'a> JacobiPrecond<'a> {
    /// Wrap `op`; requires a strictly positive diagonal (SPD matrices
    /// qualify). Returns `None` if any diagonal entry is ≤ 0.
    pub fn new(op: &'a dyn SymOp) -> Option<Self> {
        let diag = op.diagonal();
        if diag.iter().any(|&d| d <= 0.0) {
            return None;
        }
        let d_scale: Vec<f64> = diag.iter().map(|&d| 1.0 / d.sqrt()).collect();
        let n = op.dim();
        Some(JacobiPrecond {
            op,
            d_scale,
            scratch: std::sync::Mutex::new((vec![0.0; n], vec![0.0; n])),
        })
    }

    /// The transformed query vector `C u = D^{-1/2} u`; run GQL on
    /// (`self`, `scaled_query(u)`) to bound the original BIF.
    pub fn scaled_query(&self, u: &[f64]) -> Vec<f64> {
        u.iter().zip(&self.d_scale).map(|(x, s)| x * s).collect()
    }
}

impl SymOp for JacobiPrecond<'_> {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let mut guard = self.scratch.lock().expect("scratch lock poisoned");
        let (sx, sy) = &mut *guard;
        for ((t, &xi), &s) in sx.iter_mut().zip(x).zip(&self.d_scale) {
            *t = xi * s;
        }
        self.op.matvec(sx, sy);
        for ((yi, &ti), &s) in y.iter_mut().zip(sy.iter()).zip(&self.d_scale) {
            *yi = ti * s;
        }
    }

    fn diagonal(&self) -> Vec<f64> {
        // D^{-1/2} A D^{-1/2} has unit diagonal by construction.
        vec![1.0; self.op.dim()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{sym_eigenvalues, Cholesky, DMat};
    use crate::quadrature::gql::tests::random_shifted_spd;
    use crate::quadrature::{Gql, GqlOptions};
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Rng;

    #[test]
    fn preconditioned_bif_equals_original() {
        forall(20, 0x9C1, |rng| {
            let n = 4 + rng.below(16);
            let (a, _, _) = random_shifted_spd(rng, n, 0.6, 0.5);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = Cholesky::factor(&a).unwrap().bif(&u);
            let pc = JacobiPrecond::new(&a).unwrap();
            let su = pc.scaled_query(&u);
            // exact BIF of the transformed problem via dense materialization
            let mut m = DMat::zeros(n, n);
            for j in 0..n {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                let mut col = vec![0.0; n];
                pc.matvec(&e, &mut col);
                for i in 0..n {
                    m.set(i, j, col[i]);
                }
            }
            let exact_pc = Cholesky::factor(&m).unwrap().bif(&su);
            assert_close(exact_pc, exact, 1e-9, 1e-10);
        });
    }

    #[test]
    fn gql_on_preconditioned_op_brackets_original_value() {
        let mut rng = Rng::new(0x9C2);
        // badly scaled diagonal: Jacobi helps a lot here
        let n = 24;
        let (mut a, _, _) = random_shifted_spd(&mut rng, n, 0.5, 0.5);
        for i in 0..n {
            let s = 10f64.powi((i % 5) as i32);
            for j in 0..n {
                let v = a.get(i, j) * s.sqrt() * (10f64.powi((j % 5) as i32)).sqrt();
                a.set(i, j, v);
            }
        }
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let pc = JacobiPrecond::new(&a).unwrap();
        let su = pc.scaled_query(&u);
        // materialize to get a valid window for the transformed spectrum
        let mut m = DMat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let mut col = vec![0.0; n];
            pc.matvec(&e, &mut col);
            for i in 0..n {
                m.set(i, j, col[i]);
            }
        }
        let ev = sym_eigenvalues(&m);
        let opts = GqlOptions::new(ev[0] * 0.99, ev[n - 1] * 1.01);
        let mut q = Gql::new(&pc, &su, opts);
        let b = q.run_to_gap(1e-6 * exact.abs());
        assert!(b.lower() <= exact * (1.0 + 1e-6));
        assert!(b.upper() >= exact * (1.0 - 1e-6));
    }

    #[test]
    fn preconditioning_reduces_condition_number() {
        let mut rng = Rng::new(0x9C3);
        let n = 16;
        let (mut a, _, _) = random_shifted_spd(&mut rng, n, 0.5, 1.0);
        // scale rows/cols badly
        for i in 0..n {
            for j in 0..n {
                let s = (1 + i % 4 * 10) as f64 * (1 + j % 4 * 10) as f64;
                a.set(i, j, a.get(i, j) * s.sqrt());
            }
        }
        let ev = sym_eigenvalues(&a);
        let pc = JacobiPrecond::new(&a).unwrap();
        let mut m = DMat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let mut col = vec![0.0; n];
            pc.matvec(&e, &mut col);
            for i in 0..n {
                m.set(i, j, col[i]);
            }
        }
        let ev_pc = sym_eigenvalues(&m);
        let kappa = ev[n - 1] / ev[0];
        let kappa_pc = ev_pc[n - 1] / ev_pc[0];
        assert!(
            kappa_pc < kappa,
            "jacobi should help here: {kappa_pc} vs {kappa}"
        );
    }

    #[test]
    fn rejects_nonpositive_diagonal() {
        let mut a = DMat::eye(3);
        a.set(1, 1, 0.0);
        assert!(JacobiPrecond::new(&a).is_none());
    }

    #[test]
    fn unit_diagonal_reported() {
        let mut rng = Rng::new(0x9C4);
        let (a, _, _) = random_shifted_spd(&mut rng, 8, 0.5, 0.5);
        let pc = JacobiPrecond::new(&a).unwrap();
        assert_eq!(pc.diagonal(), vec![1.0; 8]);
    }
}
