//! Retrospective judges (paper Alg. 4, 7, 9): run quadrature *just far
//! enough* to decide a comparison involving BIFs, never farther.
//!
//! Each judge returns both the decision and [`JudgeStats`] (iterations
//! actually spent) — the iteration histograms in EXPERIMENTS.md come from
//! these.
//!
//! **Deprecation note (ISSUE 4).** The one-shot entry points here are
//! kept as thin compatibility wrappers over the unified query planner
//! ([`crate::quadrature::query::Session`]): [`judge_threshold`] submits a
//! single [`Query::Threshold`](crate::quadrature::query::Query) and
//! [`judge_ratio_block`] a single
//! [`Query::Compare`](crate::quadrature::query::Query). Prefer the
//! session for new code — it accepts an arbitrary *mix* of co-keyed
//! queries against one operator and shares panel sweeps across them,
//! which a one-query wrapper cannot. The explicit-[`BoundSource`] and
//! explicit-[`RefinePolicy`] variants remain hand-rolled scalar loops:
//! they exist to ablate scheduling/bound choices the planner fixes.

use super::gql::{Bounds, Gql, GqlOptions};
use super::is_zero;
use super::query::{Answer, Query, Session};
use super::race::RacePolicy;
use crate::sparse::SymOp;

/// How a judgement terminated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JudgeOutcome {
    /// Bounds separated before exhaustion — the cheap case the paper's
    /// speedups come from.
    Decided,
    /// Krylov exhaustion made the value exact first.
    Exact,
    /// Iteration budget hit; decision taken at the bracket midpoint
    /// (never happens with the default unlimited budget).
    Budget,
}

/// Accounting for one judgement.
#[derive(Clone, Copy, Debug)]
pub struct JudgeStats {
    pub iters: usize,
    pub outcome: JudgeOutcome,
}

/// Which pair of bound sequences a judge separates on. The paper proves
/// Radau dominates at equal iteration count (Thm. 4/6) — ablated in
/// `bench_ablation`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundSource {
    /// right Gauss-Radau (lower) + left Gauss-Radau (upper) — the default
    Radau,
    /// Gauss (lower) + Gauss-Lobatto (upper) — strictly weaker per Thm. 4/6
    GaussLobatto,
}

/// Paper Alg. 4 (DPPJudge): is `t < u^T A^{-1} u`?
///
/// Iterates Gauss-Radau until `t < g^rr` (true) or `t ≥ g^lr` (false).
///
/// Since ISSUE 4 this is a thin wrapper over the unified planner — a
/// width-1 [`Session`] carrying one threshold query, whose lane is
/// bit-identical to the scalar loop (decision, iteration count, and
/// outcome all match [`judge_threshold_src`] with
/// [`BoundSource::Radau`], property-tested). Callers with several
/// queries against one operator should submit them to a single session
/// instead, where they share panel sweeps.
pub fn judge_threshold(
    op: &dyn SymOp,
    u: &[f64],
    t: f64,
    opts: GqlOptions,
) -> (bool, JudgeStats) {
    if is_zero(u) {
        // u = 0 ⇒ BIF = 0 exactly (disconnected candidate: common on the
        // paper's very sparse matrices)
        return (t < 0.0, JudgeStats { iters: 0, outcome: JudgeOutcome::Exact });
    }
    let mut session = Session::new(op, opts, 1, RacePolicy::Prune);
    let qid = session.submit(Query::Threshold { u: u.to_vec(), t });
    match session.run(op).swap_remove(qid) {
        Answer::Threshold { decision, stats } => (decision, stats),
        _ => unreachable!("threshold queries answer with threshold answers"),
    }
}

/// [`judge_threshold`] with an explicit [`BoundSource`] (ablation entry).
pub fn judge_threshold_src(
    op: &dyn SymOp,
    u: &[f64],
    t: f64,
    opts: GqlOptions,
    src: BoundSource,
) -> (bool, JudgeStats) {
    if is_zero(u) {
        // u = 0 ⇒ BIF = 0 exactly (disconnected candidate: common on the
        // paper's very sparse matrices)
        return (t < 0.0, JudgeStats { iters: 0, outcome: JudgeOutcome::Exact });
    }
    let mut q = Gql::new(op, u, opts);
    loop {
        let b = q.step();
        if b.exact {
            return (t < b.gauss, JudgeStats { iters: b.iter, outcome: JudgeOutcome::Exact });
        }
        let (lo, hi) = match src {
            BoundSource::Radau => (b.radau_lower, b.radau_upper),
            BoundSource::GaussLobatto => (b.gauss, b.lobatto),
        };
        if t < lo {
            return (true, JudgeStats { iters: b.iter, outcome: JudgeOutcome::Decided });
        }
        if t >= hi {
            return (false, JudgeStats { iters: b.iter, outcome: JudgeOutcome::Decided });
        }
        if q.iterations() >= opts.max_iters {
            return (t < b.mid(), JudgeStats { iters: b.iter, outcome: JudgeOutcome::Budget });
        }
    }
}

/// How a two-sided judge picks which quadrature to advance next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefinePolicy {
    /// §5.1: tighten whichever side contributes the larger (p-weighted)
    /// bracket — the paper's refinement.
    Adaptive,
    /// strictly alternate sides (the straw-man the refinement improves on)
    Alternate,
}

/// Paper Alg. 7 (kDPP-JudgeGauss): is `t < p·(v^T A^{-1} v) − u^T A^{-1} u`?
///
/// Runs two interleaved quadratures on the same operator and applies the
/// §5.1 refinement: tighten whichever side currently contributes the larger
/// (p-scaled) bracket to the undecidable region.
pub fn judge_ratio(
    op: &dyn SymOp,
    u: &[f64],
    v: &[f64],
    t: f64,
    p: f64,
    opts: GqlOptions,
) -> (bool, JudgeStats) {
    judge_ratio_policy(op, u, v, t, p, opts, RefinePolicy::Adaptive)
}

/// [`judge_ratio`] with an explicit [`RefinePolicy`] (ablation entry).
pub fn judge_ratio_policy(
    op: &dyn SymOp,
    u: &[f64],
    v: &[f64],
    t: f64,
    p: f64,
    opts: GqlOptions,
    policy: RefinePolicy,
) -> (bool, JudgeStats) {
    // zero queries have exactly-zero BIFs; swap in an exhausted bracket
    let zero_bounds = |iter| crate::quadrature::Bounds {
        iter,
        gauss: 0.0,
        radau_lower: 0.0,
        radau_upper: 0.0,
        lobatto: 0.0,
        exact: true,
    };
    let mut qu = (!is_zero(u)).then(|| Gql::new(op, u, opts));
    let mut qv = (!is_zero(v)).then(|| Gql::new(op, v, opts));
    let mut bu = qu.as_mut().map_or(zero_bounds(0), |q| q.step());
    let mut bv = qv.as_mut().map_or(zero_bounds(0), |q| q.step());
    loop {
        // decide / tie-break / budget: one ladder shared with the paired
        // block driver (ratio_verdict), so the two variants cannot drift
        if let Some(r) = ratio_verdict(&bu, &bv, t, p, opts.max_iters) {
            return r;
        }
        let du = bu.gap();
        let dv = p * bv.gap();
        // refinement: adaptive per §5.1 or strict alternation (ablation)
        let prefer_u = match policy {
            RefinePolicy::Adaptive => du >= dv,
            RefinePolicy::Alternate => (bu.iter + bv.iter) % 2 == 0,
        };
        let tighten_u = (prefer_u && !bu.exact && bu.iter < opts.max_iters)
            || bv.exact
            || bv.iter >= opts.max_iters;
        if tighten_u {
            bu = qu.as_mut().map_or(bu, |q| q.step());
        } else {
            bv = qv.as_mut().map_or(bv, |q| q.step());
        }
    }
}

/// [`judge_ratio`] routed through **paired panel lanes**: both
/// quadratures advance from one width-2 `matvec_multi` panel sweep — a
/// single traversal of the shared operator per iteration instead of two —
/// with the survivor continuing alone once one side finishes.
///
/// Since ISSUE 4 this is a thin wrapper over the unified planner: one
/// [`Query::Compare`](crate::quadrature::query::Query) on a width-2
/// [`Session`], which replaced the hand-rolled interleaved panel this
/// function used to carry. Decisions are certified by the same Radau
/// brackets (and the same `ratio_verdict` ladder) as the scalar judge,
/// so wherever both variants decide before their budgets they agree; only
/// the refinement *schedule* differs (lockstep instead of the §5.1
/// looser-side heuristic). MH k-DPP chains route the swap test through
/// the session directly.
pub fn judge_ratio_block(
    op: &dyn SymOp,
    u: &[f64],
    v: &[f64],
    t: f64,
    p: f64,
    opts: GqlOptions,
) -> (bool, JudgeStats) {
    let mut session = Session::new(op, opts, 2, RacePolicy::Prune);
    let qid = session.submit(Query::Compare { u: u.to_vec(), v: v.to_vec(), t, p });
    match session.run(op).swap_remove(qid) {
        Answer::Compare { decision, stats } => (decision, stats),
        _ => unreachable!("compare queries answer with compare answers"),
    }
}

/// Joint verdict for a ratio judgement from the two current brackets:
/// `Some` once decidable *or* once neither side can refine further (so
/// the drivers always terminate), `None` while at least one side can
/// still tighten an undecided bracket. Shared by [`judge_ratio_policy`]
/// and the planner's compare queries
/// ([`crate::quadrature::query::Session`]) — one ladder, no drift. A side
/// counts as stuck when it is exact (exhausted: stepping it again cannot
/// move the bracket) *or* out of budget; requiring both iteration counts
/// to reach `max_iters` used to livelock the scalar judge when one side
/// exhausted early while the other sat at its budget (ISSUE 2 edge case).
pub(crate) fn ratio_verdict(
    bu: &Bounds,
    bv: &Bounds,
    t: f64,
    p: f64,
    max_iters: usize,
) -> Option<(bool, JudgeStats)> {
    let iters = bu.iter + bv.iter;
    let outcome = if bu.exact && bv.exact { JudgeOutcome::Exact } else { JudgeOutcome::Decided };
    if t < p * bv.lower() - bu.upper() {
        return Some((true, JudgeStats { iters, outcome }));
    }
    if t >= p * bv.upper() - bu.lower() {
        return Some((false, JudgeStats { iters, outcome }));
    }
    if bu.exact && bv.exact {
        // fully exact yet undecidable can only be a tie: break by <
        let val = p * bv.gauss - bu.gauss;
        return Some((t < val, JudgeStats { iters, outcome: JudgeOutcome::Exact }));
    }
    let u_stuck = bu.exact || bu.iter >= max_iters;
    let v_stuck = bv.exact || bv.iter >= max_iters;
    if u_stuck && v_stuck {
        // at least one side is out of budget: decide at the midpoints,
        // like the scalar judge (exact sides have collapsed brackets)
        let val = p * bv.mid() - bu.mid();
        return Some((t < val, JudgeStats { iters, outcome: JudgeOutcome::Budget }));
    }
    None
}

/// Paper Alg. 9 (DG-JudgeGauss): double-greedy inclusion test.
///
/// With Δ⁺ = log(l_ii − u_x^T L_X^{-1} u_x) (gain of adding `i` to X) and
/// Δ⁻ = −log(l_ii − u_y^T L_{Y'}^{-1} u_y) (gain of removing `i` from Y),
/// returns true (add to X) iff `p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊`.
///
/// `ops` may be `None` when the corresponding set is empty (Δ then depends
/// on `l_ii` alone and is exact).
///
/// Since ISSUE 3 this is a thin wrapper over the comparison race
/// [`crate::quadrature::race::race_dg`] under
/// [`RacePolicy::Prune`](crate::quadrature::race::RacePolicy) — decide
/// the moment the log-gap brackets separate, the judge's original
/// semantics.
pub fn judge_dg(
    op_x: Option<(&dyn SymOp, &[f64])>,
    op_y: Option<(&dyn SymOp, &[f64])>,
    l_ii: f64,
    p: f64,
    opts_x: GqlOptions,
    opts_y: GqlOptions,
) -> (bool, JudgeStats) {
    super::race::race_dg(
        op_x,
        op_y,
        l_ii,
        p,
        opts_x,
        opts_y,
        super::race::RacePolicy::Prune,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, DMat};
    use crate::quadrature::gql::tests::random_shifted_spd;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng, n: usize) -> (DMat, Vec<f64>, GqlOptions, f64) {
        let (a, l1, ln) = random_shifted_spd(rng, n, 0.6, 0.2);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        (a, u, GqlOptions::new(l1 * 0.99, ln * 1.01), exact)
    }

    #[test]
    fn threshold_judge_always_matches_exact_comparison() {
        forall(40, 0x701, |rng| {
            let n = 4 + rng.below(24);
            let (a, u, opts, exact) = setup(rng, n);
            // thresholds straddling the value at various distances
            for factor in [0.5, 0.9, 0.999, 1.001, 1.1, 2.0] {
                let t = exact * factor;
                let (ans, stats) = judge_threshold(&a, &u, t, opts);
                assert_eq!(ans, t < exact, "factor={factor}");
                assert!(stats.iters <= n + 1);
            }
        });
    }

    #[test]
    fn easy_thresholds_decide_in_few_iterations() {
        let mut rng = Rng::new(0x702);
        let (a, u, opts, exact) = setup(&mut rng, 64);
        let (_, far) = judge_threshold(&a, &u, exact * 0.01, opts);
        let (_, near) = judge_threshold(&a, &u, exact * 0.999, opts);
        assert!(
            far.iters <= near.iters,
            "far {} vs near {}",
            far.iters,
            near.iters
        );
        assert!(far.iters < 64, "far threshold should decide early");
    }

    #[test]
    fn ratio_judge_matches_exact_comparison() {
        forall(30, 0x703, |rng| {
            let n = 5 + rng.below(20);
            let (a, l1, ln) = random_shifted_spd(rng, n, 0.6, 0.2);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let ch = Cholesky::factor(&a).unwrap();
            let (eu, ev) = (ch.bif(&u), ch.bif(&v));
            let opts = GqlOptions::new(l1 * 0.99, ln * 1.01);
            for p in [0.1, 0.5, 0.9] {
                let truth_val = p * ev - eu;
                for t in [truth_val - 0.5, truth_val * 0.9, truth_val + 0.5] {
                    if (t - truth_val).abs() < 1e-9 {
                        continue;
                    }
                    let (ans, _) = judge_ratio(&a, &u, &v, t, p, opts);
                    assert_eq!(ans, t < truth_val, "p={p} t={t} truth={truth_val}");
                }
            }
        });
    }

    #[test]
    fn paired_block_ratio_judge_matches_exact_comparison() {
        // mirror of ratio_judge_matches_exact_comparison through the
        // paired-panel path: lockstep refinement must reach the same
        // certified decisions
        forall(30, 0x708, |rng| {
            let n = 5 + rng.below(20);
            let (a, l1, ln) = random_shifted_spd(rng, n, 0.6, 0.2);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let ch = Cholesky::factor(&a).unwrap();
            let (eu, ev) = (ch.bif(&u), ch.bif(&v));
            let opts = GqlOptions::new(l1 * 0.99, ln * 1.01);
            for p in [0.1, 0.5, 0.9] {
                let truth_val = p * ev - eu;
                for t in [truth_val - 0.5, truth_val * 0.9, truth_val + 0.5] {
                    if (t - truth_val).abs() < 1e-9 {
                        continue;
                    }
                    let (ans, _) = judge_ratio_block(&a, &u, &v, t, p, opts);
                    assert_eq!(ans, t < truth_val, "p={p} t={t} truth={truth_val}");
                }
            }
        });
    }

    #[test]
    fn paired_judge_zero_sides_still_decide_exactly() {
        let mut rng = Rng::new(0x709);
        let (a, u, opts, exact) = setup(&mut rng, 16);
        let z = vec![0.0; 16];
        // v = 0 ⇒ truth = p·0 − BIF_u = −BIF_u
        let (ans, _) = judge_ratio_block(&a, &u, &z, -exact * 0.5, 0.7, opts);
        assert_eq!(ans, -exact * 0.5 < -exact);
        // u = 0 ⇒ truth = p·BIF_v
        let (ans, _) = judge_ratio_block(&a, &z, &u, exact * 0.5, 0.7, opts);
        assert_eq!(ans, exact * 0.5 < 0.7 * exact);
    }

    #[test]
    fn one_sided_exhaustion_with_budget_terminates() {
        // u lives in a 2-dim invariant subspace (breakdown ⇒ exact at
        // iter 2) while v is capped at 4 iterations. The old budget
        // condition required *both* iteration counts to reach max_iters,
        // which could never happen: the judge spun forever re-stepping
        // the exhausted side (ISSUE 2 edge case). Both variants must now
        // terminate with a bounded iteration total.
        let mut rng = Rng::new(0x70A);
        let m = 30;
        let (b2, _, ln2) = random_shifted_spd(&mut rng, m, 1.0, 0.5);
        let n = m + 2;
        let mut a = DMat::zeros(n, n);
        a.set(0, 0, 2.0);
        a.set(1, 1, 2.0);
        a.set(0, 1, 0.3);
        a.set(1, 0, 0.3);
        for i in 0..m {
            for j in 0..m {
                a.set(2 + i, 2 + j, b2.get(i, j));
            }
        }
        let mut u = vec![0.0; n];
        u[0] = 1.0;
        u[1] = -0.5;
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(0.4, ln2.max(2.3) * 1.1).with_max_iters(4);
        let ch = Cholesky::factor(&a).unwrap();
        let truth = 0.5 * ch.bif(&v) - ch.bif(&u);
        // a threshold just off the truth: likely inside the budget-limited
        // bracket, i.e. the exact case that used to livelock
        let t = truth + 1e-12 * (1.0 + truth.abs());
        let (_, js) = judge_ratio(&a, &u, &v, t, 0.5, opts);
        assert!(js.iters <= 8, "scalar ratio judge ran away ({} iters)", js.iters);
        let (_, jb) = judge_ratio_block(&a, &u, &v, t, 0.5, opts);
        assert!(jb.iters <= 8, "paired ratio judge ran away ({} iters)", jb.iters);
    }

    #[test]
    fn dg_judge_matches_exact_decision() {
        forall(30, 0x704, |rng| {
            let n = 6 + rng.below(16);
            let (a, l1, ln) = random_shifted_spd(rng, n, 0.7, 0.3);
            // split indices into X and Y' with a candidate element i
            let k = 2 + rng.below(n / 2);
            let all = rng.sample_indices(n, n);
            let (xs, rest) = all.split_at(k);
            let (ys, _) = rest.split_at(rng.below(rest.len().max(2) - 1) + 1);
            let i = *all.last().unwrap();
            let full = a.clone();
            let ax = full.principal_submatrix(xs);
            let ay = full.principal_submatrix(ys);
            let ux: Vec<f64> = xs.iter().map(|&m| full.get(m, i)).collect();
            let uy: Vec<f64> = ys.iter().map(|&m| full.get(m, i)).collect();
            let l_ii = full.get(i, i);
            let chx = Cholesky::factor(&ax);
            let chy = Cholesky::factor(&ay);
            let (chx, chy) = match (chx, chy) {
                (Ok(a), Ok(b)) => (a, b),
                _ => return, // random submatrix not PD: skip case
            };
            let dp = (l_ii - chx.bif(&ux)).max(1e-300).ln();
            let dm = -(l_ii - chy.bif(&uy)).max(1e-300).ln();
            let opts = GqlOptions::new(l1 * 0.5, ln * 1.5);
            for p in [0.2, 0.5, 0.8] {
                let want = p * dm.max(0.0) <= (1.0 - p) * dp.max(0.0);
                let (got, _) = judge_dg(
                    Some((&ax, &ux)),
                    Some((&ay, &uy)),
                    l_ii,
                    p,
                    opts,
                    opts,
                );
                assert_eq!(got, want, "p={p} dp={dp} dm={dm}");
            }
        });
    }

    #[test]
    fn dg_judge_empty_sides_are_exact() {
        // X empty, Y empty: Δ⁺ = log(l_ii), Δ⁻ = −log(l_ii), no quadrature.
        let l_ii = 2.0;
        let opts = GqlOptions::new(0.1, 10.0);
        let (ans, stats) = judge_dg(None, None, l_ii, 0.3, opts, opts);
        // Δ⁺ = ln 2 > 0, Δ⁻ = −ln 2 → [Δ⁻]₊ = 0 ⇒ always add
        assert!(ans);
        assert_eq!(stats.iters, 0);
        assert_eq!(stats.outcome, JudgeOutcome::Exact);
    }

    #[test]
    fn budget_falls_back_to_midpoint() {
        let mut rng = Rng::new(0x705);
        let (a, u, opts, exact) = setup(&mut rng, 48);
        let tight = opts.with_max_iters(2);
        // threshold so close the 2-iteration bracket cannot decide
        let (ans, stats) = judge_threshold(&a, &u, exact * (1.0 - 1e-12), tight);
        // must terminate quickly either way
        assert!(stats.iters <= 2);
        if stats.outcome == JudgeOutcome::Budget {
            // midpoint decision is allowed to be either; just check sanity
            let _ = ans;
        }
    }
}
