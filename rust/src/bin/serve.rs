//! `serve` — resident multi-tenant serving demo (ISSUE 7).
//!
//! One resident [`Engine`] serves a sustained mixed workload end to end:
//! a load generator streams estimate / threshold / compare queries
//! against hundreds of keyed tenant operators, tagging a fraction with
//! round deadlines; the engine admits them through the deadline-checked
//! path (shedding the least-urgent in-flight estimate at the queue cap —
//! every shed answer is still a certified four-bound bracket), runs the
//! joint round loop a few steps per tick (streaming, never a full
//! stop-the-world drain), and retires answers with
//! [`Engine::take_answer`] so the resident ticket log compacts. Idle
//! tenant operators demote to the byte-budgeted warm store and re-admit
//! by key alone — the load generator counts how often the cold
//! (operator-shipping) path was actually needed.
//!
//! A reporter thread prints live counters from the shared metrics
//! registry, including one final post-drain line at shutdown.
//! SIGINT/SIGTERM — or the `--seconds` timer — triggers a graceful
//! shutdown: stop admitting, drain in-flight queries, export the final
//! `engine.*`/`serve.*` snapshot, join the reporter, and exit nonzero if
//! any harvested bracket was invalid or any ticket was lost.
//!
//! A `--trace-frac` slice of the stream is stochastic (ISSUE 9):
//! `Trace`/`LogDet` queries whose probe panels coalesce with the
//! bilinear traffic on the same tenant key. Their answers — shed or
//! fully run — must carry a valid combined interval, audited exactly
//! like the estimate brackets.
//!
//! Observability (ISSUE 10): the engine's query-lifecycle flight
//! recorder is on by default (`--flight false` disables it), and a
//! std-only HTTP listener (`--http ADDR`, default an ephemeral localhost
//! port, `off` disables) exposes `/metrics` (Prometheus text),
//! `/healthz`, and `/queries` (live in-flight spans with their current
//! four-bound brackets and rounds-elapsed). On a bracket violation, a
//! worker panic, or SIGUSR1 the recorder is dumped as JSON — to
//! `--flight-dump FILE` when given, stderr otherwise — naming the
//! offending span. `--inject-violation N` fires a synthetic violation on
//! the Nth answer so the post-mortem path can be exercised end to end
//! (injected violations dump but do not fail the run).
//!
//! ```text
//! serve [--seconds S] [--keys K] [--dim N] [--queue-cap C]
//!       [--store-kb KB] [--burst B] [--trace-frac F] [--seed X]
//!       [--telemetry FILE] [--http ADDR|off] [--flight true|false]
//!       [--flight-dump FILE] [--inject-violation N]
//! ```
//!
//! `BENCH_QUICK=1` shrinks every default to CI-smoke scale.

use gauss_bif::datasets::random_spd_exact;
use gauss_bif::metrics::export::{to_prometheus, write_json};
use gauss_bif::metrics::flight::{FlightEventKind, FlightRecorder, SpanId};
use gauss_bif::metrics::MetricsRegistry;
use gauss_bif::quadrature::engine::{Engine, EngineConfig, OpKey, SubmitError, Ticket};
use gauss_bif::quadrature::query::{Answer, Query};
use gauss_bif::quadrature::stochastic::{SlqConfig, SpectralFn, StochasticReport};
use gauss_bif::quadrature::{GqlOptions, StopRule};
use gauss_bif::sparse::SymOp;
use gauss_bif::util::rng::Rng;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Set by the signal handler (and only ever read elsewhere): the load
/// loop checks it every tick, so delivery-to-drain latency is one tick.
static STOP: AtomicBool = AtomicBool::new(false);

/// Set by SIGUSR1: the load loop dumps the flight recorder on the next
/// tick without stopping.
static DUMP: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_sig: i32) {
    STOP.store(true, Ordering::SeqCst);
}

extern "C" fn on_usr1(_sig: i32) {
    DUMP.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
fn install_signal_handlers() {
    // libc `signal` declared directly: the crate is dependency-free and
    // an AtomicBool store is async-signal-safe.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    const SIGUSR1: i32 = 10;
    unsafe {
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
        signal(SIGUSR1, on_usr1 as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

struct Opts {
    seconds: f64,
    keys: usize,
    dim: usize,
    queue_cap: usize,
    store_kb: usize,
    burst: usize,
    /// Fraction of the query stream that is stochastic (Trace/LogDet).
    trace_frac: f64,
    seed: u64,
    telemetry: Option<PathBuf>,
    /// Scrape listener address, or `off`.
    http: String,
    /// Query-lifecycle flight recorder on/off.
    flight: bool,
    /// Post-mortem dump destination (stderr when unset).
    flight_dump: Option<PathBuf>,
    /// Fire a synthetic bracket violation on the Nth answer (0 = never).
    inject_violation: u64,
}

const USAGE: &str = "usage: serve [--seconds S] [--keys K] [--dim N] [--queue-cap C]\n\
                     \x20            [--store-kb KB] [--burst B] [--trace-frac F] [--seed X]\n\
                     \x20            [--telemetry FILE] [--http ADDR|off] [--flight true|false]\n\
                     \x20            [--flight-dump FILE] [--inject-violation N]\n\
                     BENCH_QUICK=1 shrinks the defaults to CI-smoke scale";

fn parse_bool(name: &str, v: &str) -> Result<bool, String> {
    match v {
        "true" | "1" | "on" => Ok(true),
        "false" | "0" | "off" => Ok(false),
        other => Err(format!("{name} wants true|false (got {other})\n{USAGE}")),
    }
}

fn parse_opts() -> Result<Opts, String> {
    let quick = std::env::var("BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let mut o = if quick {
        Opts {
            seconds: 2.0,
            keys: 64,
            dim: 16,
            queue_cap: 48,
            store_kb: 0, // filled below from keys × dim
            burst: 8,
            trace_frac: 0.15,
            seed: 0x5EB1F,
            telemetry: None,
            http: "127.0.0.1:0".to_string(),
            flight: true,
            flight_dump: None,
            inject_violation: 0,
        }
    } else {
        Opts {
            seconds: 10.0,
            keys: 256,
            dim: 32,
            queue_cap: 192,
            store_kb: 0,
            burst: 16,
            trace_frac: 0.15,
            seed: 0x5EB1F,
            telemetry: None,
            http: "127.0.0.1:0".to_string(),
            flight: true,
            flight_dump: None,
            inject_violation: 0,
        }
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut val = |name: &str| args.next().ok_or(format!("{name} needs a value"));
        match a.as_str() {
            "--seconds" => o.seconds = val("--seconds")?.parse().map_err(|e| format!("{e}"))?,
            "--keys" => o.keys = val("--keys")?.parse().map_err(|e| format!("{e}"))?,
            "--dim" => o.dim = val("--dim")?.parse().map_err(|e| format!("{e}"))?,
            "--queue-cap" => o.queue_cap = val("--queue-cap")?.parse().map_err(|e| format!("{e}"))?,
            "--store-kb" => o.store_kb = val("--store-kb")?.parse().map_err(|e| format!("{e}"))?,
            "--burst" => o.burst = val("--burst")?.parse().map_err(|e| format!("{e}"))?,
            "--trace-frac" => {
                o.trace_frac = val("--trace-frac")?.parse().map_err(|e| format!("{e}"))?
            }
            "--seed" => o.seed = val("--seed")?.parse().map_err(|e| format!("{e}"))?,
            "--telemetry" => o.telemetry = Some(PathBuf::from(val("--telemetry")?)),
            "--http" => o.http = val("--http")?,
            "--flight" => o.flight = parse_bool("--flight", &val("--flight")?)?,
            "--flight-dump" => o.flight_dump = Some(PathBuf::from(val("--flight-dump")?)),
            "--inject-violation" => {
                o.inject_violation =
                    val("--inject-violation")?.parse().map_err(|e| format!("{e}"))?
            }
            "--help" | "-h" => return Err(USAGE.to_string()),
            other => return Err(format!("unknown flag {other}\n{USAGE}")),
        }
    }
    o.keys = o.keys.max(2);
    o.dim = o.dim.max(4);
    o.queue_cap = o.queue_cap.max(1);
    o.burst = o.burst.max(1);
    if !(0.0..=1.0).contains(&o.trace_frac) {
        return Err(format!("--trace-frac must lie in [0, 1] (got {})\n{USAGE}", o.trace_frac));
    }
    if o.store_kb == 0 {
        // budget ~a quarter of the tenant population so the soak
        // actually exercises LRU eviction and warm re-admission
        o.store_kb = (o.keys * o.dim * o.dim * 8 / 4 / 1024).max(4);
    }
    Ok(o)
}

/// One tenant: a keyed SPD operator the load generator queries again and
/// again. The `Arc` here is the *cold-path* copy — after first admission
/// the engine's store owns its own clone and warm submissions ship no
/// operator at all.
struct Tenant {
    key: OpKey,
    op: Arc<gauss_bif::linalg::DMat>,
    opts: GqlOptions,
    dim: usize,
    lam_max: f64,
}

fn make_query(rng: &mut Rng, t: &Tenant, trace_frac: f64) -> Query {
    if rng.f64() < trace_frac {
        // stochastic slice: few probes, loose tolerance — serving wants
        // the anytime interval, not a tight estimate. A fresh seed per
        // query keeps tenant panels decorrelated.
        let cfg = SlqConfig::new(4, rng.next_u64(), 5e-2);
        return if rng.bool(0.5) {
            Query::Trace { f: SpectralFn::Inverse, cfg }
        } else {
            Query::LogDet { cfg }
        };
    }
    let u: Vec<f64> = (0..t.dim).map(|_| rng.normal()).collect();
    match rng.below(3) {
        0 => Query::Estimate { u, stop: StopRule::GapRel(1e-3) },
        1 => {
            // u^T A^{-1} u ≥ |u|²/λmax, so thresholds drawn around that
            // scale split both ways instead of being trivially decided
            let floor = u.iter().map(|x| x * x).sum::<f64>() / t.lam_max;
            let tv = floor * rng.range_f64(0.5, 2.5);
            Query::Threshold { u, t: tv }
        }
        _ => {
            let v: Vec<f64> = (0..t.dim).map(|_| rng.normal()).collect();
            Query::Compare { u, v, t: 0.0, p: rng.range_f64(0.5, 1.5) }
        }
    }
}

/// `lower ≤ upper`, both finite: what every harvested estimate — shed or
/// fully run — must satisfy (the anytime property the admission layer
/// leans on).
fn bracket_valid(b: &gauss_bif::quadrature::Bounds) -> bool {
    let tol = 1e-9 * b.upper().abs().max(1.0);
    b.lower().is_finite() && b.upper().is_finite() && b.lower() <= b.upper() + tol
}

/// The stochastic analogue: every harvested Trace/LogDet answer — shed
/// mid-flight or run to its stop rule — must carry a finite, ordered
/// combined interval containing its own estimate, fed by ≥ 1 probe.
fn interval_valid(r: &StochasticReport) -> bool {
    let tol = 1e-9 * r.combined.hi.abs().max(1.0);
    r.combined.lo.is_finite()
        && r.combined.hi.is_finite()
        && r.combined.lo <= r.combined.hi + tol
        && r.combined.lo - tol <= r.estimate
        && r.estimate <= r.combined.hi + tol
        && r.probes_contributing >= 1
}

/// One-shot injection check: fire once, on the first bracket-carrying
/// answer at or past the target count.
fn inject_due(target: u64, answered: u64, fired: &mut bool) -> bool {
    if target == 0 || *fired || answered < target {
        return false;
    }
    *fired = true;
    true
}

/// Write the post-mortem: the recorder dump wrapped with the trigger
/// reason and (when known) the offending span — to `path` when given,
/// stderr otherwise. Non-fatal on IO errors: the run's verdict comes
/// from the bracket audit, not the dump.
fn dump_flight(
    flight: Option<&FlightRecorder>,
    path: Option<&Path>,
    reason: &str,
    span: Option<SpanId>,
) {
    let Some(f) = flight else {
        eprintln!("flight dump requested ({reason}) but the recorder is off (--flight false)");
        return;
    };
    let mut out = String::from("{\"reason\": \"");
    out.push_str(reason);
    out.push_str("\", \"violation_span\": ");
    match span {
        Some(s) => out.push_str(&s.to_string()),
        None => out.push_str("null"),
    }
    out.push_str(", \"recorder\": ");
    out.push_str(&f.to_json());
    out.push_str("}\n");
    match path {
        Some(p) => {
            if let Some(dir) = p.parent() {
                if !dir.as_os_str().is_empty() {
                    let _ = std::fs::create_dir_all(dir);
                }
            }
            match std::fs::write(p, &out) {
                Ok(()) => println!("flight dump ({reason}): {}", p.display()),
                Err(e) => eprintln!("flight dump ({reason}) write failed: {e}"),
            }
        }
        None => eprintln!("flight dump ({reason}): {out}"),
    }
}

/// Render the engine's in-flight spans as the `/queries` JSON payload.
/// Multi-lane kinds have no single bracket: their bound fields are null.
fn render_live(eng: &Engine) -> String {
    let jnum = |v: f64| -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "null".to_string()
        }
    };
    let mut out = String::from("{\"version\": 1, \"rounds\": ");
    out.push_str(&eng.stats().rounds.to_string());
    out.push_str(", \"spans\": [");
    for (i, s) in eng.live_spans().iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "{{\"span\": {}, \"key\": {}, \"rounds_elapsed\": {}, \"parked\": {}",
            s.span, s.key, s.rounds_elapsed, s.parked
        ));
        match &s.bounds {
            Some(b) => out.push_str(&format!(
                ", \"iter\": {}, \"gauss\": {}, \"radau_lower\": {}, \"radau_upper\": {}, \
                 \"lobatto\": {}}}",
                b.iter,
                jnum(b.gauss),
                jnum(b.radau_lower),
                jnum(b.radau_upper),
                jnum(b.lobatto)
            )),
            None => out.push_str(
                ", \"iter\": null, \"gauss\": null, \"radau_lower\": null, \
                 \"radau_upper\": null, \"lobatto\": null}",
            ),
        }
    }
    out.push_str("]}\n");
    out
}

/// Answer one scrape connection: `/metrics` (Prometheus text),
/// `/healthz`, `/queries` (pre-rendered live-span JSON). Std-only
/// HTTP/1.1, one request per connection.
fn serve_http(mut sock: TcpStream, reg: &MetricsRegistry, queries: &Mutex<String>) {
    let _ = sock.set_nonblocking(false);
    let _ = sock.set_read_timeout(Some(Duration::from_millis(500)));
    let mut buf = [0u8; 1024];
    let n = match sock.read(&mut buf) {
        Ok(n) if n > 0 => n,
        _ => return,
    };
    let req = String::from_utf8_lossy(&buf[..n]);
    let target = req.split_whitespace().nth(1).unwrap_or("/");
    let (status, ctype, body) = match target {
        "/metrics" => ("200 OK", "text/plain; version=0.0.4", to_prometheus(&reg.snapshot())),
        "/healthz" => ("200 OK", "text/plain", "ok\n".to_string()),
        "/queries" => (
            "200 OK",
            "application/json",
            match queries.lock() {
                Ok(g) => g.clone(),
                Err(poisoned) => poisoned.into_inner().clone(),
            },
        ),
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    };
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    let _ = sock.write_all(head.as_bytes());
    let _ = sock.write_all(body.as_bytes());
}

fn main() -> ExitCode {
    let o = match parse_opts() {
        Ok(o) => o,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    install_signal_handlers();
    let reg = Arc::new(MetricsRegistry::new());
    let mut rng = Rng::new(o.seed);

    println!(
        "serve: {} tenants (dim {}..{}), queue cap {}, store budget {} KiB, {:.1}s",
        o.keys,
        o.dim,
        o.dim + 12,
        o.queue_cap,
        o.store_kb,
        o.seconds
    );

    // tenant pool: hundreds of distinct keyed operators, dims jittered so
    // panels differ and the store budget bites unevenly
    let tenants: Vec<Tenant> = (0..o.keys)
        .map(|k| {
            let dim = o.dim + 4 * (k % 4);
            let (a, l1, ln) = random_spd_exact(&mut rng, dim, 0.5, 0.2);
            Tenant {
                key: k as OpKey,
                op: Arc::new(a),
                opts: GqlOptions::new(l1 * 0.99, ln * 1.01),
                dim,
                lam_max: ln * 1.01,
            }
        })
        .collect();

    let ecfg = EngineConfig::default()
        .with_width(8)
        .with_lanes(128)
        .with_ttl_rounds(64)
        .with_store_bytes(o.store_kb * 1024)
        .with_queue_cap(o.queue_cap)
        .with_flight(o.flight);
    let mut eng = match Engine::new(ecfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine config rejected: {e}");
            return ExitCode::from(2);
        }
    };
    // the recorder outlives every engine borrow: dumps and scrapes read
    // it through this clone while the round loop mutates the engine
    let flight = eng.flight().cloned();

    // reporter thread (satellite b: on stop it flushes one final
    // post-drain report line before exiting, so the console log ends
    // with the state the telemetry snapshot was written from)
    let report_stop = Arc::new(AtomicBool::new(false));
    let reporter = {
        let reg = Arc::clone(&reg);
        let stop = Arc::clone(&report_stop);
        std::thread::spawn(move || {
            let mut slept_ms = 0u64;
            loop {
                std::thread::sleep(Duration::from_millis(50));
                let stopped = stop.load(Ordering::SeqCst);
                slept_ms += 50;
                if !stopped && slept_ms < 500 {
                    continue;
                }
                slept_ms = 0;
                let snap = reg.snapshot();
                let g = |name: &str| -> f64 {
                    match snap.get(name) {
                        Some(gauss_bif::metrics::MetricValue::Gauge(v)) => *v,
                        Some(gauss_bif::metrics::MetricValue::Counter(c)) => *c as f64,
                        _ => 0.0,
                    }
                };
                println!(
                    "  [report{}] rounds={} open={} resident={} ({:.0} KiB) evicted={} shed={} compactions={}",
                    if stopped { " final" } else { "" },
                    g("engine.rounds"),
                    g("engine.open_tickets"),
                    g("engine.store.resident"),
                    g("engine.store.resident_bytes") / 1024.0,
                    g("engine.store.evicted"),
                    g("engine.admission.shed"),
                    g("engine.admission.compactions"),
                );
                if stopped {
                    break;
                }
            }
        })
    };

    // scrape listener: ephemeral port by default (the bound address is
    // printed for scrapers to pick up), `--http off` disables
    let queries_json =
        Arc::new(Mutex::new(String::from("{\"version\": 1, \"rounds\": 0, \"spans\": []}\n")));
    let http = if o.http == "off" {
        None
    } else {
        match TcpListener::bind(&o.http) {
            Ok(listener) => {
                let addr = listener
                    .local_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| o.http.clone());
                println!("http: listening on {addr} (/metrics /healthz /queries)");
                let _ = listener.set_nonblocking(true);
                let reg = Arc::clone(&reg);
                let queries = Arc::clone(&queries_json);
                let stop = Arc::clone(&report_stop);
                Some(std::thread::spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((sock, _)) => serve_http(sock, &reg, &queries),
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(25));
                            }
                            Err(_) => std::thread::sleep(Duration::from_millis(25)),
                        }
                    }
                }))
            }
            Err(e) => {
                eprintln!("http: bind {} failed ({e}); introspection disabled", o.http);
                None
            }
        }
    };
    let http_on = http.is_some();

    let deadline_t = Instant::now() + Duration::from_secs_f64(o.seconds);
    let mut inflight: Vec<Ticket> = Vec::new();
    let (mut submitted, mut refused, mut answered) = (0u64, 0u64, 0u64);
    let (mut warm, mut cold) = (0u64, 0u64);
    let mut bracket_bad = 0u64;
    let mut stochastic = 0u64;
    let mut injected_fired = false;

    while !STOP.load(Ordering::SeqCst) && Instant::now() < deadline_t {
        if DUMP.swap(false, Ordering::SeqCst) {
            dump_flight(flight.as_deref(), o.flight_dump.as_deref(), "sigusr1", None);
        }
        // streaming submission: a burst of keyed queries, warm path first
        // (no operator crosses the API), cold path ships the Arc once
        for _ in 0..o.burst {
            let t = &tenants[rng.below(tenants.len())];
            let q = make_query(&mut rng, t, o.trace_frac);
            let dl = if rng.bool(0.5) { Some(8 + rng.below(64) as u64) } else { None };
            let res = match eng.submit_keyed(t.key, t.opts, q.clone(), dl) {
                Err(SubmitError::UnknownKey(_)) => {
                    cold += 1;
                    eng.try_submit(t.key, Arc::clone(&t.op) as Arc<dyn SymOp>, t.opts, q, dl)
                }
                other => {
                    if other.is_ok() {
                        warm += 1;
                    }
                    other
                }
            };
            match res {
                Ok(tk) => {
                    submitted += 1;
                    inflight.push(tk);
                }
                Err(SubmitError::Saturated) => refused += 1,
                Err(SubmitError::UnknownKey(k)) => {
                    unreachable!("cold path preloads key {k}")
                }
            }
        }
        // advance the joint schedule a few rounds — never a full drain,
        // so admission, shedding, and eviction interleave with progress.
        // A worker panic dumps the recorder before propagating: the
        // post-mortem survives even when the process does not.
        let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for _ in 0..4 {
                if !eng.step_round() {
                    break;
                }
            }
        }));
        if let Err(payload) = stepped {
            dump_flight(flight.as_deref(), o.flight_dump.as_deref(), "worker_panic", None);
            std::panic::resume_unwind(payload);
        }
        // harvest what resolved; take_answer compacts the ticket log. A
        // violated bracket (or the --inject-violation drill) records a
        // BracketViolation on the span and dumps the recorder.
        let mut violation: Option<(Option<SpanId>, &'static str)> = None;
        inflight.retain(|&tk| {
            if eng.answer(tk).is_none() {
                return true;
            }
            let span = eng.span_of(tk);
            match eng.take_answer(tk) {
                Ok(Answer::Estimate { bounds, .. }) => {
                    answered += 1;
                    let bad = !bracket_valid(&bounds);
                    if bad {
                        bracket_bad += 1;
                    }
                    if bad || inject_due(o.inject_violation, answered, &mut injected_fired) {
                        let why = if bad { "bracket_violation" } else { "injected_violation" };
                        violation = Some((span, why));
                    }
                }
                Ok(Answer::Stochastic(r)) => {
                    answered += 1;
                    stochastic += 1;
                    let bad = !interval_valid(&r);
                    if bad {
                        bracket_bad += 1;
                    }
                    if bad || inject_due(o.inject_violation, answered, &mut injected_fired) {
                        let why = if bad { "bracket_violation" } else { "injected_violation" };
                        violation = Some((span, why));
                    }
                }
                Ok(_) => answered += 1,
                Err(e) => unreachable!("freshly answered ticket turned {e:?}"),
            }
            false
        });
        if let Some((span, reason)) = violation.take() {
            if let (Some(f), Some(s)) = (flight.as_ref(), span) {
                f.record(s, FlightEventKind::BracketViolation);
            }
            dump_flight(flight.as_deref(), o.flight_dump.as_deref(), reason, span);
        }
        eng.export_into(&reg);
        reg.set_gauge("serve.inflight", inflight.len() as f64);
        reg.set_counter("serve.submitted", submitted);
        reg.set_counter("serve.refused", refused);
        reg.set_counter("serve.answered", answered);
        if http_on {
            let rendered = render_live(&eng);
            match queries_json.lock() {
                Ok(mut g) => *g = rendered,
                Err(poisoned) => *poisoned.into_inner() = rendered,
            }
        }
    }

    // graceful shutdown: stop admitting, run the engine dry, harvest the
    // stragglers (shed ones resolved early — their brackets count too)
    let reason = if STOP.load(Ordering::SeqCst) { "signal" } else { "timer" };
    println!("shutdown ({reason}): draining {} in-flight queries", inflight.len());
    eng.drain();
    let mut lost = 0u64;
    let mut violation: Option<(Option<SpanId>, &'static str)> = None;
    for tk in inflight.drain(..) {
        let span = eng.span_of(tk);
        match eng.take_answer(tk) {
            Ok(Answer::Estimate { bounds, .. }) => {
                answered += 1;
                if !bracket_valid(&bounds) {
                    bracket_bad += 1;
                    violation = Some((span, "bracket_violation"));
                }
            }
            Ok(Answer::Stochastic(r)) => {
                answered += 1;
                stochastic += 1;
                if !interval_valid(&r) {
                    bracket_bad += 1;
                    violation = Some((span, "bracket_violation"));
                }
            }
            Ok(_) => answered += 1,
            Err(_) => lost += 1,
        }
    }
    if let Some((span, why)) = violation.take() {
        if let (Some(f), Some(s)) = (flight.as_ref(), span) {
            f.record(s, FlightEventKind::BracketViolation);
        }
        dump_flight(flight.as_deref(), o.flight_dump.as_deref(), why, span);
    }

    let st = eng.stats();
    eng.export_into(&reg);
    reg.set_counter("serve.submitted", submitted);
    reg.set_counter("serve.refused", refused);
    reg.set_counter("serve.answered", answered);
    reg.set_counter("serve.warm_submits", warm);
    reg.set_counter("serve.cold_submits", cold);
    reg.set_counter("serve.stochastic_answers", stochastic);
    reg.set_counter("serve.bracket_violations", bracket_bad);
    reg.set_counter("serve.lost_tickets", lost);
    reg.set_gauge("serve.inflight", 0.0);
    if http_on {
        let rendered = render_live(&eng);
        match queries_json.lock() {
            Ok(mut g) => *g = rendered,
            Err(poisoned) => *poisoned.into_inner() = rendered,
        }
    }
    // stop the side threads only now: the reporter's final line and any
    // last scrape see the post-drain exported state
    report_stop.store(true, Ordering::SeqCst);
    let _ = reporter.join();
    if let Some(h) = http {
        let _ = h.join();
    }
    if let Some(path) = &o.telemetry {
        match write_json(path, &reg.snapshot()) {
            Ok(()) => println!("telemetry snapshot: {}", path.display()),
            Err(e) => {
                eprintln!("telemetry write failed: {e}");
                return ExitCode::from(1);
            }
        }
    }

    println!(
        "served {answered}/{submitted} ({warm} warm, {cold} cold admissions, {refused} refused at cap, {stochastic} stochastic)"
    );
    println!(
        "engine: {} rounds, {} sweeps, shed {} (anytime brackets), store evicted {}, compacted {}",
        st.rounds,
        st.sweeps,
        st.shed,
        eng.store().evicted(),
        st.compactions,
    );
    if injected_fired {
        println!("injected violation drill fired (see flight dump)");
    }
    if bracket_bad > 0 || lost > 0 {
        eprintln!("FAILED: {bracket_bad} invalid brackets, {lost} lost tickets");
        return ExitCode::from(1);
    }
    println!("clean shutdown");
    ExitCode::SUCCESS
}
