//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every experiment in the repo is reproducible from a single `u64` seed;
//! streams can be forked ([`Rng::fork`]) so parallel components stay
//! decorrelated without sharing state.

/// xoshiro256** (Blackman & Vigna) — 256-bit state, passes BigCrush,
/// sub-nanosecond per draw. Plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is fine: SplitMix64
    /// expands it to a full non-zero 256-bit state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. one per thread / per experiment
    /// repetition) from this one.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// A *splittable* stream: a generator that is a pure function of
    /// `(seed, index)` — unlike [`Rng::fork`], no sequential draws are
    /// consumed, so stream `i` is identical no matter how many other
    /// streams were opened first or on which worker. The stochastic
    /// quadrature layer keys each probe vector on its probe index through
    /// this, which is what makes SLQ answers independent of worker count
    /// and sweep mode.
    pub fn stream(seed: u64, index: u64) -> Rng {
        // run the index through one SplitMix64 scramble before mixing it
        // into the seed so streams 0,1,2,… land far apart in seed space
        let mut sm = index.wrapping_add(0x632B_E593_7689_87C5);
        let scrambled = splitmix64(&mut sm);
        Rng::new(seed ^ scrambled)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's rejection-free-ish method with
    /// a widening multiply; unbiased via rejection on the low word).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
            if lo >= lo.wrapping_sub(n) % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Uniform random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var={m2}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn stream_is_pure_in_seed_and_index() {
        // same (seed, index) ⇒ bit-equal draws, regardless of what other
        // streams exist or in which order they were opened
        let mut a = Rng::stream(0xB1F, 3);
        let _ = Rng::stream(0xB1F, 0); // unrelated stream, no effect
        let mut b = Rng::stream(0xB1F, 3);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // neighboring indices and differing seeds decorrelate
        let mut c = Rng::stream(0xB1F, 4);
        let mut d = Rng::stream(0xB20, 3);
        let mut a = Rng::stream(0xB1F, 3);
        let same_idx = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        let mut a = Rng::stream(0xB1F, 3);
        let same_seed = (0..64).filter(|_| a.next_u64() == d.next_u64()).count();
        assert_eq!(same_idx, 0);
        assert_eq!(same_seed, 0);
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(9);
        let mut b = a.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
