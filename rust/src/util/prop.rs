//! Tiny property-testing runner (proptest is not in the offline cache).
//!
//! ```ignore
//! use gauss_bif::util::prop::forall;
//! forall(64, 0xC0FFEE, |rng| {
//!     let n = 2 + rng.below(30);
//!     // ... build a random case, assert the invariant ...
//! });
//! ```
//!
//! Each case gets a fresh fork of the master stream; on panic the harness
//! reports the case index and its per-case seed so the failure replays with
//! [`replay`].

use super::rng::Rng;

/// Run `prop` on `cases` random cases derived from `seed`. Panics (with the
/// replay seed) on the first failing case.
pub fn forall<F: FnMut(&mut Rng) + std::panic::UnwindSafe + Copy>(
    cases: usize,
    seed: u64,
    prop: F,
) {
    let mut master = Rng::new(seed);
    for i in 0..cases {
        let case_seed = master.next_u64();
        let result = std::panic::catch_unwind(move || {
            let mut rng = Rng::new(case_seed);
            let mut p = prop;
            p(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {i}/{cases} (replay seed: {case_seed:#x}):\n  {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F: FnMut(&mut Rng)>(case_seed: u64, mut prop: F) {
    let mut rng = Rng::new(case_seed);
    prop(&mut rng);
}

/// Assert two floats agree to a relative (plus absolute floor) tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, rtol: f64, atol: f64) {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs());
    assert!(
        diff <= atol + rtol * scale,
        "assert_close failed: {a} vs {b} (diff {diff:.3e} > atol {atol:.1e} + rtol {rtol:.1e} * {scale:.3e})"
    );
}

/// Assert `a <= b` up to tolerance (used for bound-ordering properties).
#[track_caller]
pub fn assert_le(a: f64, b: f64, tol: f64) {
    assert!(a <= b + tol, "assert_le failed: {a} > {b} + {tol:.1e}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(32, 1, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn forall_reports_failure_with_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(16, 2, |rng| {
                // fails eventually
                assert!(rng.f64() < 0.5, "coin came up heads");
            });
        });
        let err = r.expect_err("property should have failed");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "msg: {msg}");
    }

    #[test]
    fn assert_close_accepts_and_rejects() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, 0.0);
        let r = std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-3, 0.0));
        assert!(r.is_err());
    }
}
