//! Minimal criterion-style bench harness (criterion is not in the offline
//! crate cache).  Measures wall-clock over adaptive batches, reports
//! mean / median / p95 / stddev, and renders aligned tables so each
//! `benches/bench_*.rs` can print the same rows the paper's tables report.

use std::time::{Duration, Instant};

/// One benchmark measurement summary (nanoseconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Human units: "123.4 ns", "4.56 µs", "7.8 ms", "1.2 s".
    pub fn fmt_time(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} mean {:>10}  median {:>10}  p95 {:>10}  (±{:>9}, n={})",
            self.name,
            Stats::fmt_time(self.mean_ns),
            Stats::fmt_time(self.median_ns),
            Stats::fmt_time(self.p95_ns),
            Stats::fmt_time(self.stddev_ns),
            self.samples
        )
    }
}

/// Bench runner. Defaults: 0.2 s warmup, 1 s measurement, ≤ 200 samples —
/// tuned so a full `cargo bench` run fits the session budget while keeping
/// stddev small on ms-scale routines.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
    pub min_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_samples: 200,
            min_samples: 5,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for expensive end-to-end drivers (few samples).
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            max_samples: 30,
            min_samples: 3,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which must perform one unit of the benchmarked work and
    /// return a value (consumed via `std::hint::black_box` to keep the
    /// optimizer honest).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure
        let mut times = Vec::with_capacity(self.max_samples);
        let start = Instant::now();
        while (start.elapsed() < self.measure || times.len() < self.min_samples)
            && times.len() < self.max_samples
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Self::summarize(name, &mut times);
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    fn summarize(name: &str, times: &mut [f64]) -> Stats {
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / n.max(2) as f64;
        Stats {
            name: name.to_string(),
            samples: n,
            mean_ns: mean,
            median_ns: times[n / 2],
            p95_ns: times[(n as f64 * 0.95) as usize % n],
            stddev_ns: var.sqrt(),
            min_ns: times[0],
            max_ns: times[n - 1],
        }
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

/// Aligned markdown-ish table printer used by the table-reproduction benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }
}

/// Format a speedup column the way Table 2 does ("17.8x").
pub fn fmt_speedup(baseline_s: f64, ours_s: f64) -> String {
    if ours_s <= 0.0 {
        return "inf".into();
    }
    format!("{:.1}x", baseline_s / ours_s)
}

/// Format seconds in the paper's scientific style ("9.6E-3").
pub fn fmt_sci(secs: f64) -> String {
    if secs == 0.0 {
        return "0".into();
    }
    let exp = secs.abs().log10().floor() as i32;
    if (-2..4).contains(&exp) {
        format!("{secs:.3}")
    } else {
        format!("{:.1}E{}", secs / 10f64.powi(exp), exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            max_samples: 50,
            min_samples: 3,
            results: vec![],
        };
        let s = b.bench("noop-ish", || (0..100).sum::<u64>());
        assert!(s.samples >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["data", "time", "speedup"]);
        t.row(vec!["abalone".into(), "9.6E-3".into(), "17.8x".into()]);
        t.row(vec!["x".into(), "1".into(), "2x".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[0].contains("data"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(Stats::fmt_time(500.0), "500.0 ns");
        assert_eq!(Stats::fmt_time(2_500.0), "2.50 µs");
        assert_eq!(fmt_speedup(10.0, 1.0), "10.0x");
        assert_eq!(fmt_sci(0.0096), "9.6E-3");
        assert_eq!(fmt_sci(1025.6), "1025.600");
    }
}
