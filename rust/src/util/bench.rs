//! Minimal criterion-style bench harness (criterion is not in the offline
//! crate cache).  Measures wall-clock over adaptive batches, reports
//! mean / median / p95 / stddev, and renders aligned tables so each
//! `benches/bench_*.rs` can print the same rows the paper's tables report.

use std::io::Write;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// One benchmark measurement summary (nanoseconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// A one-sample summary for drivers that time a single end-to-end run
    /// (the figure/table reproductions) but still want to land in the
    /// perf-trajectory JSON next to the sampled benches.
    pub fn single(name: &str, ns: f64) -> Stats {
        Stats {
            name: name.to_string(),
            samples: 1,
            mean_ns: ns,
            median_ns: ns,
            p95_ns: ns,
            stddev_ns: 0.0,
            min_ns: ns,
            max_ns: ns,
        }
    }

    /// Human units: "123.4 ns", "4.56 µs", "7.8 ms", "1.2 s".
    pub fn fmt_time(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} mean {:>10}  median {:>10}  p95 {:>10}  (±{:>9}, n={})",
            self.name,
            Stats::fmt_time(self.mean_ns),
            Stats::fmt_time(self.median_ns),
            Stats::fmt_time(self.p95_ns),
            Stats::fmt_time(self.stddev_ns),
            self.samples
        )
    }
}

/// Bench runner. Defaults: 0.2 s warmup, 1 s measurement, ≤ 200 samples —
/// tuned so a full `cargo bench` run fits the session budget while keeping
/// stddev small on ms-scale routines.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
    pub min_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_samples: 200,
            min_samples: 5,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Default profile — except under `BENCH_QUICK=1` (the CI smoke
    /// setting), which swaps in the [`Bencher::quick`] knobs so a full
    /// bench suite finishes in seconds.
    pub fn new() -> Self {
        if std::env::var_os("BENCH_QUICK").is_some() {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Quick profile for expensive end-to-end drivers (few samples).
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            max_samples: 30,
            min_samples: 3,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which must perform one unit of the benchmarked work and
    /// return a value (consumed via `std::hint::black_box` to keep the
    /// optimizer honest).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure
        let mut times = Vec::with_capacity(self.max_samples);
        let start = Instant::now();
        while (start.elapsed() < self.measure || times.len() < self.min_samples)
            && times.len() < self.max_samples
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Self::summarize(name, &mut times);
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    fn summarize(name: &str, times: &mut [f64]) -> Stats {
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / n.max(2) as f64;
        Stats {
            name: name.to_string(),
            samples: n,
            mean_ns: mean,
            median_ns: times[n / 2],
            p95_ns: times[(n as f64 * 0.95) as usize % n],
            stddev_ns: var.sqrt(),
            min_ns: times[0],
            max_ns: times[n - 1],
        }
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Write every result this runner has accumulated as the
    /// `BENCH_<bench>.json` perf-trajectory artifact (see
    /// [`write_stats_json`]).
    pub fn write_json(&self, bench: &str) -> std::io::Result<PathBuf> {
        write_stats_json(bench, &self.results)
    }
}

/// Emit a perf-trajectory artifact `BENCH_<bench>.json` under the
/// directory named by the `BENCH_OUT` env var (default `results/`),
/// creating the directory if needed. Returns the path written.
///
/// Schema (version 1), times in nanoseconds:
/// `{"bench": "...", "version": 1, "results":
///   [{"name": "...", "mean": ns, "median": ns, "p95": ns, "n": samples}]}`
///
/// The output round-trips through the crate's own `config::json` parser,
/// so CI can validate emitted artifacts without external tooling.
pub fn write_stats_json(bench: &str, stats: &[Stats]) -> std::io::Result<PathBuf> {
    use crate::metrics::export::{json_escape, json_num};
    let dir = std::env::var_os("BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{bench}.json"));
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench)));
    out.push_str("  \"version\": 1,\n  \"results\": [");
    for (i, s) in stats.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"name\": \"{}\", \"mean\": {}, \"median\": {}, \"p95\": {}, \"n\": {}}}",
            json_escape(&s.name),
            json_num(s.mean_ns),
            json_num(s.median_ns),
            json_num(s.p95_ns),
            s.samples
        ));
    }
    out.push_str("\n  ]\n}\n");
    let mut f = std::fs::File::create(&path)?;
    f.write_all(out.as_bytes())?;
    Ok(path)
}

/// Aligned markdown-ish table printer used by the table-reproduction benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }
}

/// Format a speedup column the way Table 2 does ("17.8x").
pub fn fmt_speedup(baseline_s: f64, ours_s: f64) -> String {
    if ours_s <= 0.0 {
        return "inf".into();
    }
    format!("{:.1}x", baseline_s / ours_s)
}

/// Format seconds in the paper's scientific style ("9.6E-3").
pub fn fmt_sci(secs: f64) -> String {
    if secs == 0.0 {
        return "0".into();
    }
    let exp = secs.abs().log10().floor() as i32;
    if (-2..4).contains(&exp) {
        format!("{secs:.3}")
    } else {
        format!("{:.1}E{}", secs / 10f64.powi(exp), exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            max_samples: 50,
            min_samples: 3,
            results: vec![],
        };
        let s = b.bench("noop-ish", || (0..100).sum::<u64>());
        assert!(s.samples >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["data", "time", "speedup"]);
        t.row(vec!["abalone".into(), "9.6E-3".into(), "17.8x".into()]);
        t.row(vec!["x".into(), "1".into(), "2x".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[0].contains("data"));
    }

    #[test]
    fn stats_json_round_trips_through_our_own_parser() {
        let dir = std::env::temp_dir().join("gauss_bif_bench_json_test");
        // the env var is process-global; tests in this binary run in
        // threads, so scope the override to this one writer call order
        std::env::set_var("BENCH_OUT", &dir);
        let stats =
            vec![Stats::single("scalar n=64", 1234.5), Stats::single("panel \"w8\"", 8e6)];
        let path = write_stats_json("smoke", &stats).expect("write succeeds");
        std::env::remove_var("BENCH_OUT");
        assert!(path.ends_with("BENCH_smoke.json"), "unexpected path {path:?}");
        let text = std::fs::read_to_string(&path).expect("artifact readable");
        let doc = crate::config::json::parse(&text).expect("artifact parses");
        assert_eq!(doc.get("bench").and_then(|b| b.as_str()), Some("smoke"));
        assert_eq!(doc.get("version").and_then(|v| v.as_f64()), Some(1.0));
        let results =
            doc.get("results").and_then(|r| r.as_arr()).expect("results array");
        assert_eq!(results.len(), 2);
        let first = &results[0];
        assert_eq!(first.get("name").and_then(|n| n.as_str()), Some("scalar n=64"));
        assert_eq!(first.get("mean").and_then(|m| m.as_f64()), Some(1234.5));
        assert_eq!(first.get("n").and_then(|n| n.as_f64()), Some(1.0));
        let second = &results[1];
        assert_eq!(second.get("name").and_then(|n| n.as_str()), Some("panel \"w8\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(Stats::fmt_time(500.0), "500.0 ns");
        assert_eq!(Stats::fmt_time(2_500.0), "2.50 µs");
        assert_eq!(fmt_speedup(10.0, 1.0), "10.0x");
        assert_eq!(fmt_sci(0.0096), "9.6E-3");
        assert_eq!(fmt_sci(1025.6), "1025.600");
    }
}
