//! In-repo substitutes for crates the offline image does not carry:
//! a deterministic PRNG ([`rng`]), a criterion-style bench harness
//! ([`bench`]) and a small property-testing runner ([`prop`]).

pub mod bench;
pub mod prop;
pub mod rng;
