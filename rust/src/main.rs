//! `gauss-bif` launcher: regenerate the paper's tables/figures, validate
//! the theory, or run the judge service demo.
//!
//! Usage:
//!   gauss-bif fig1   [--seed S] [--out DIR] [--iters N]
//!   gauss-bif fig2   [--seed S] [--out DIR] [--scale K] [--densities d1,d2,...]
//!   gauss-bif table2 [--seed S] [--out DIR] [--scale K] [--datasets N] [--dg-limit L]
//!   gauss-bif rates  [--seed S] [--out DIR] [--sizes n1,n2,...]
//!   gauss-bif block  [--seed S] [--out DIR] [--scale K] [--ks k1,k2,...] [--block-width B]
//!   gauss-bif race   [--seed S] [--out DIR] [--scale K] [--ks k1,k2,...] [--block-width B]
//!   gauss-bif session [--seed S] [--out DIR] [--scale K] [--ks k1,k2,...]
//!   gauss-bif engine [--seed S] [--out DIR] [--scale K] [--chains c1,c2,...]
//!                    [--engine-lanes L] [--engine-ttl T] [--engine-workers W]
//!   gauss-bif slq    [--seed S] [--out DIR] [--sizes n1,n2,...]
//!                    [--slq-probes P] [--slq-seed S] [--slq-tol T]
//!   gauss-bif serve  [--artifacts DIR] [--requests N] [--workers W] [--block-width B]
//!   gauss-bif info   [--artifacts DIR]
//!
//! A JSON run config can seed the defaults: `--config path.json`
//! (see config::run::RunConfig).
//!
//! Any command accepts `--telemetry FILE`: after the run, the process-wide
//! metrics registry (experiment gauges, engine round profile, service
//! counters — whatever the command populated) is dumped as a JSON
//! snapshot to FILE (see metrics::export).

use gauss_bif::config::RunConfig;
use gauss_bif::experiments::{self, fig1, fig2, rates, table2};
use gauss_bif::metrics::MetricsRegistry;
use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, flags)) = parse_args(&args) else {
        eprintln!("{}", USAGE);
        return ExitCode::from(2);
    };

    let mut cfg = match flags.get("config") {
        Some(path) => match RunConfig::load(&PathBuf::from(path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return ExitCode::from(2);
            }
        },
        None => RunConfig::default(),
    };
    if let Some(s) = flags.get("seed").and_then(|s| s.parse().ok()) {
        cfg.seed = s;
    }
    if let Some(s) = flags.get("out") {
        cfg.out_dir = PathBuf::from(s);
    }
    if let Some(s) = flags.get("scale").and_then(|s| s.parse().ok()) {
        cfg.dataset_scale = s;
    }
    if let Some(s) = flags.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(s);
    }
    if let Some(s) = flags.get("block-width").and_then(|s| s.parse::<usize>().ok()) {
        cfg.block_width = s.max(1);
    }
    if let Some(s) = flags.get("reorth") {
        // `--reorth full` (or true/1) enables §5.4 full reorthogonalization
        // for config-driven quadrature runs; `--reorth none` (or false/0)
        // disables. Case-insensitive, matching the JSON parser; anything
        // else is a usage error rather than a silent no.
        if ["full", "true", "1"].iter().any(|v| s.eq_ignore_ascii_case(v)) {
            cfg.reorth = true;
        } else if ["none", "false", "0"].iter().any(|v| s.eq_ignore_ascii_case(v)) {
            cfg.reorth = false;
        } else {
            eprintln!("invalid --reorth value '{s}' (expected full|none)\n{USAGE}");
            return ExitCode::from(2);
        }
    }
    if let Some(s) = flags.get("race") {
        // `--race prune` (or true/1) enables interval-dominance pruning
        // for config-driven greedy scoring; `--race exhaustive` (or
        // false/0) scores every candidate to tolerance. Selections are
        // identical either way (quadrature::race's guarantee) — the knob
        // trades panel sweeps for none.
        if ["prune", "true", "1"].iter().any(|v| s.eq_ignore_ascii_case(v)) {
            cfg.race = true;
        } else if ["exhaustive", "false", "0"].iter().any(|v| s.eq_ignore_ascii_case(v)) {
            cfg.race = false;
        } else {
            eprintln!("invalid --race value '{s}' (expected prune|exhaustive)\n{USAGE}");
            return ExitCode::from(2);
        }
    }

    if let Some(s) = flags.get("flight") {
        // `--flight on` (or true/1) keeps the engine's query-lifecycle
        // flight recorder armed for config-driven runs; `--flight off`
        // (or false/0) drops it. Answers are bit-identical either way —
        // the knob only trades a bounded event ring for its overhead.
        if ["on", "true", "1"].iter().any(|v| s.eq_ignore_ascii_case(v)) {
            cfg.flight = true;
        } else if ["off", "false", "0"].iter().any(|v| s.eq_ignore_ascii_case(v)) {
            cfg.flight = false;
        } else {
            eprintln!("invalid --flight value '{s}' (expected on|off)\n{USAGE}");
            return ExitCode::from(2);
        }
    }

    // engine scheduling knobs, validated at admission with the typed
    // error (ISSUE 5 satellite — mirrors the BatchPolicy rejection path)
    if let Some(s) = flags.get("engine-lanes").and_then(|s| s.parse::<usize>().ok()) {
        cfg.engine_lanes = s;
    }
    if let Some(s) = flags.get("engine-ttl").and_then(|s| s.parse::<usize>().ok()) {
        cfg.engine_ttl_rounds = s;
    }
    if let Some(s) = flags.get("engine-workers").and_then(|s| s.parse::<usize>().ok()) {
        cfg.engine_workers = s.clamp(1, 1 << 10);
    }
    if let Err(e) = gauss_bif::quadrature::engine::EngineConfig::validate_knobs(
        cfg.engine_lanes,
        cfg.engine_ttl_rounds,
    ) {
        eprintln!("invalid engine knobs: {e}\n{USAGE}");
        return ExitCode::from(2);
    }

    // stochastic quadrature knobs (ISSUE 9 satellite): overrides land on
    // the config, then the combined SlqConfig is validated once with the
    // typed error — the same rejection the engine applies at admission
    if let Some(s) = flags.get("slq-probes").and_then(|s| s.parse::<usize>().ok()) {
        cfg.slq_probes = s;
    }
    if let Some(s) = flags.get("slq-seed").and_then(|s| s.parse::<u64>().ok()) {
        cfg.slq_seed = s;
    }
    if let Some(s) = flags.get("slq-tol").and_then(|s| s.parse::<f64>().ok()) {
        cfg.slq_tol = s;
    }
    if let Err(e) = cfg.slq_config().validate() {
        eprintln!("invalid stochastic knobs: {e}\n{USAGE}");
        return ExitCode::from(2);
    }

    // one registry for the whole run; commands that have telemetry to
    // publish receive `Some(&reg)` and the snapshot lands at the flagged
    // path after the command returns (whatever its exit code)
    let telemetry = flags.get("telemetry").map(PathBuf::from);
    let reg = MetricsRegistry::new();
    let treg = telemetry.as_ref().map(|_| &reg);
    let t0 = std::time::Instant::now();

    let code = match cmd.as_str() {
        "fig1" => cmd_fig1(&cfg, &flags),
        "fig2" => cmd_fig2(&cfg, &flags),
        "table2" => cmd_table2(&cfg, &flags),
        "rates" => cmd_rates(&cfg, &flags, treg),
        "block" => cmd_block(&cfg, &flags),
        "race" => cmd_race(&cfg, &flags),
        "session" => cmd_session(&cfg, &flags),
        "engine" => cmd_engine(&cfg, &flags),
        "slq" => cmd_slq(&cfg, &flags, treg),
        "serve" => cmd_serve(&cfg, &flags, treg),
        "info" => cmd_info(&cfg),
        _ => {
            eprintln!("unknown command '{cmd}'\n{USAGE}");
            return ExitCode::from(2);
        }
    };

    if let Some(path) = telemetry {
        reg.set_gauge("run.wall_time_s", t0.elapsed().as_secs_f64());
        match gauss_bif::metrics::export::write_json(&path, &reg.snapshot()) {
            Ok(()) => println!("telemetry snapshot: {}", path.display()),
            Err(e) => {
                eprintln!("telemetry write failed: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    code
}

const USAGE: &str = "usage: gauss-bif <fig1|fig2|table2|rates|block|race|session|engine|slq|serve|info> [flags]\n\
  common flags: --seed S --out DIR --scale K --config cfg.json --artifacts DIR --block-width B\n\
                --reorth full|none (§5.4 Lanczos reorthogonalization for block/serve runs)\n\
                --race prune|exhaustive (candidate racing for greedy scoring; selections identical)\n\
                --engine-lanes L --engine-ttl T --engine-workers W (multi-operator engine knobs;\n\
                0/absurd values are rejected at admission)\n\
                --slq-probes P --slq-seed S --slq-tol T (stochastic trace/logdet knobs;\n\
                0 probes / non-positive tolerance are rejected at admission)\n\
                --flight on|off (engine query-lifecycle flight recorder; answers identical)\n\
                --telemetry FILE (dump a metrics-registry JSON snapshot after the run;\n\
                rates adds a profiled-engine pass, serve exports service counters)";

fn parse_args(args: &[String]) -> Option<(String, HashMap<String, String>)> {
    let mut it = args.iter();
    let cmd = it.next()?.clone();
    let mut flags = HashMap::new();
    while let Some(flag) = it.next() {
        let name = flag.strip_prefix("--")?.to_string();
        let value = it.next().cloned().unwrap_or_default();
        flags.insert(name, value);
    }
    Some((cmd, flags))
}

fn parse_list<T: std::str::FromStr>(s: &str) -> Vec<T> {
    s.split(',').filter_map(|x| x.trim().parse().ok()).collect()
}

fn cmd_fig1(cfg: &RunConfig, flags: &HashMap<String, String>) -> ExitCode {
    let iters = flags.get("iters").and_then(|s| s.parse().ok()).unwrap_or(60);
    let panels = fig1::run(cfg, iters);
    for p in &panels {
        println!(
            "panel {:<14} λmin={:<10.3e} λmax={:<10.3e} exact={:.6} iters-to-1%={:?}",
            p.name,
            p.lam_min,
            p.lam_max,
            p.exact,
            p.iters_to_rel_gap(0.01)
        );
    }
    let rows = fig1::csv_rows(&panels);
    match experiments::write_csv(&cfg.out_dir, "fig1.csv", &fig1::CSV_HEADER, &rows) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_fig2(cfg: &RunConfig, flags: &HashMap<String, String>) -> ExitCode {
    let densities: Vec<f64> = flags
        .get("densities")
        .map(|s| parse_list(s))
        .unwrap_or_else(|| fig2::DENSITIES.to_vec());
    let budget = fig2::Fig2Budget::default();
    let rows = fig2::run(cfg, budget, &densities);
    let mut table = gauss_bif::util::bench::Table::new(&[
        "algo", "n", "density", "baseline s/step", "gauss s/step", "speedup",
    ]);
    for r in &rows {
        table.row(vec![
            r.algo.into(),
            r.n.to_string(),
            format!("{:.0e}", r.density),
            gauss_bif::util::bench::fmt_sci(r.baseline_s),
            gauss_bif::util::bench::fmt_sci(r.gauss_s),
            format!("{:.1}x", r.speedup),
        ]);
    }
    println!("{}", table.render());
    match experiments::write_csv(&cfg.out_dir, "fig2.csv", &fig2::CSV_HEADER, &fig2::csv_rows(&rows)) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_table2(cfg: &RunConfig, flags: &HashMap<String, String>) -> ExitCode {
    let limit = flags.get("datasets").and_then(|s| s.parse().ok()).unwrap_or(6);
    let skip = flags.get("skip").and_then(|s| s.parse().ok()).unwrap_or(0);
    let mut budget = table2::Table2Budget::default();
    if let Some(l) = flags.get("dg-limit").and_then(|s| s.parse().ok()) {
        budget.dg_limit = Some(l);
    }
    if let Some(t) = flags.get("timeout").and_then(|s| s.parse().ok()) {
        budget.baseline_timeout_s = t;
    }
    if let Some(g) = flags.get("gauss-steps").and_then(|s| s.parse().ok()) {
        budget.gauss_steps = g;
    }
    let rows = table2::run_window(cfg, budget, skip, limit);
    let mut table = gauss_bif::util::bench::Table::new(&[
        "dataset", "algo", "n", "nnz", "baseline s", "gauss s", "speedup",
    ]);
    for r in &rows {
        table.row(vec![
            r.dataset.into(),
            r.algo.into(),
            r.n.to_string(),
            r.nnz.to_string(),
            r.baseline_s
                .map_or("*".into(), gauss_bif::util::bench::fmt_sci),
            gauss_bif::util::bench::fmt_sci(r.gauss_s),
            r.speedup.map_or("*".into(), |s| format!("{s:.1}x")),
        ]);
    }
    println!("{}", table.render());
    let csv_name = if skip == 0 { "table2.csv".to_string() } else { format!("table2_skip{skip}.csv") };
    match experiments::write_csv(
        &cfg.out_dir,
        &csv_name,
        &table2::CSV_HEADER,
        &table2::csv_rows(&rows),
    ) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_rates(
    cfg: &RunConfig,
    flags: &HashMap<String, String>,
    reg: Option<&MetricsRegistry>,
) -> ExitCode {
    let sizes: Vec<usize> = flags
        .get("sizes")
        .map(|s| parse_list(s))
        .unwrap_or_else(|| vec![32, 64, 128]);
    let reports = rates::run(cfg, &sizes);
    let mut ok = true;
    for r in &reports {
        let pass = r.worst_gauss <= 1.0
            && r.worst_radau_lower <= 1.0
            && r.worst_radau_upper <= 1.0
            && r.worst_lobatto <= 1.0
            && r.thm12_residual < 1e-5;
        ok &= pass;
        println!(
            "n={:<5} κ={:<10.2e} ρ={:.3} ρ̂={:.3} worst err/envelope: gauss {:.3} | radau↓ {:.3} | radau↑ {:.3} | lobatto {:.3} | thm12 {:.1e} [{}]",
            r.n,
            r.kappa,
            r.rho,
            r.fitted_rate,
            r.worst_gauss,
            r.worst_radau_lower,
            r.worst_radau_upper,
            r.worst_lobatto,
            r.thm12_residual,
            if pass { "OK" } else { "VIOLATED" }
        );
    }
    if let Some(reg) = reg {
        rates::export_registry(&reports, reg);
        // re-run the instances through a profiled engine so the snapshot
        // also carries round-phase timings and worker busy/idle fractions
        rates::profile_engine(cfg, &sizes, reg);
    }
    let _ = experiments::write_csv(
        &cfg.out_dir,
        "rates.csv",
        &rates::CSV_HEADER,
        &rates::csv_rows(&reports),
    );
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_block(cfg: &RunConfig, flags: &HashMap<String, String>) -> ExitCode {
    use gauss_bif::experiments::block;

    let ks: Vec<usize> = flags
        .get("ks")
        .map(|s| parse_list(s))
        .unwrap_or_else(|| vec![4, 16, 64]);
    let reports = block::run(cfg, &ks);
    let mut table = gauss_bif::util::bench::Table::new(&[
        "n", "nnz", "k", "width", "iters", "scalar s", "block s", "speedup", "max dev",
    ]);
    let mut exact = true;
    for r in &reports {
        exact &= r.max_dev == 0.0;
        table.row(vec![
            r.n.to_string(),
            r.nnz.to_string(),
            r.k.to_string(),
            r.width.to_string(),
            r.iters.to_string(),
            gauss_bif::util::bench::fmt_sci(r.scalar_s),
            gauss_bif::util::bench::fmt_sci(r.block_s),
            format!("{:.2}x", r.speedup),
            format!("{:.1e}", r.max_dev),
        ]);
    }
    println!("{}", table.render());
    if !exact {
        eprintln!("block path deviated from the scalar path — exactness contract broken");
        return ExitCode::FAILURE;
    }
    match experiments::write_csv(&cfg.out_dir, "block.csv", &block::CSV_HEADER, &block::csv_rows(&reports)) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_race(cfg: &RunConfig, flags: &HashMap<String, String>) -> ExitCode {
    use gauss_bif::experiments::race;

    let ks: Vec<usize> = flags
        .get("ks")
        .map(|s| parse_list(s))
        .unwrap_or_else(|| vec![4, 8, 16]);
    let reports = race::run(cfg, &ks);
    let mut table = gauss_bif::util::bench::Table::new(&[
        "n", "nnz", "k", "width", "exhaustive sweeps", "prune sweeps", "saved", "pruned arms",
        "early rounds",
    ]);
    let mut identical = true;
    let mut saved_any = false;
    for r in &reports {
        identical &= r.identical;
        saved_any |= r.prune_sweeps < r.exhaustive_sweeps;
        table.row(vec![
            r.n.to_string(),
            r.nnz.to_string(),
            r.k.to_string(),
            r.width.to_string(),
            r.exhaustive_sweeps.to_string(),
            r.prune_sweeps.to_string(),
            format!("{:.0}%", 100.0 * r.saved_frac),
            r.pruned.to_string(),
            r.decided_early.to_string(),
        ]);
    }
    println!("{}", table.render());
    if !identical {
        eprintln!("racing changed a greedy selection — dominance pruning is broken");
        return ExitCode::FAILURE;
    }
    if !saved_any {
        eprintln!("racing saved no panel sweeps on a gapped kernel — scheduler inert");
        return ExitCode::FAILURE;
    }
    match experiments::write_csv(&cfg.out_dir, "race.csv", &race::CSV_HEADER, &race::csv_rows(&reports)) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_session(cfg: &RunConfig, flags: &HashMap<String, String>) -> ExitCode {
    use gauss_bif::experiments::session;

    let ks: Vec<usize> = flags
        .get("ks")
        .map(|s| parse_list(s))
        .unwrap_or_else(|| vec![4, 8, 16]);
    let reports = session::run(cfg, &ks);
    let mut table = gauss_bif::util::bench::Table::new(&[
        "n", "nnz", "queries", "lanes", "sequential sweeps", "session sweeps", "saved",
        "pruned arms",
    ]);
    let mut identical = true;
    let mut saved_any = false;
    for r in &reports {
        identical &= r.identical;
        saved_any |= r.session_sweeps < r.sequential_sweeps;
        table.row(vec![
            r.n.to_string(),
            r.nnz.to_string(),
            r.queries.to_string(),
            r.lanes.to_string(),
            r.sequential_sweeps.to_string(),
            r.session_sweeps.to_string(),
            format!("{:.0}%", 100.0 * r.saved_frac),
            r.pruned.to_string(),
        ]);
    }
    println!("{}", table.render());
    if !identical {
        eprintln!("mixed-session answers diverged from the sequential paths");
        return ExitCode::FAILURE;
    }
    if !saved_any {
        eprintln!("co-scheduling saved no panel sweeps — the shared panel is inert");
        return ExitCode::FAILURE;
    }
    match experiments::write_csv(
        &cfg.out_dir,
        "session.csv",
        &session::CSV_HEADER,
        &session::csv_rows(&reports),
    ) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_engine(cfg: &RunConfig, flags: &HashMap<String, String>) -> ExitCode {
    use gauss_bif::experiments::engine;

    let chains: Vec<usize> = flags
        .get("chains")
        .map(|s| parse_list(s))
        .unwrap_or_else(|| vec![2, 4]);
    let reports = engine::run(cfg, &chains);
    let mut table = gauss_bif::util::bench::Table::new(&[
        "n", "dg seq", "dg joint", "saved", "chains", "kdpp seq", "kdpp joint", "saved",
        "greedy seq", "greedy joint",
    ]);
    let mut identical = true;
    let mut dg_saved = false;
    let mut kdpp_saved = false;
    for r in &reports {
        identical &= r.identical;
        dg_saved |= r.dg_joint_rounds < r.dg_sequential_rounds;
        kdpp_saved |= r.kdpp_joint_rounds < r.kdpp_sequential_rounds;
        table.row(vec![
            r.n.to_string(),
            r.dg_sequential_rounds.to_string(),
            r.dg_joint_rounds.to_string(),
            format!("{:.0}%", 100.0 * r.dg_saved_frac),
            r.kdpp_chains.to_string(),
            r.kdpp_sequential_rounds.to_string(),
            r.kdpp_joint_rounds.to_string(),
            format!("{:.0}%", 100.0 * r.kdpp_saved_frac),
            r.greedy_sequential_rounds.to_string(),
            r.greedy_joint_rounds.to_string(),
        ]);
    }
    println!("{}", table.render());
    if !identical {
        eprintln!("a joint engine workload diverged from its sequential baseline");
        return ExitCode::FAILURE;
    }
    if !dg_saved {
        eprintln!("joint scheduling saved no rounds on the double-greedy race");
        return ExitCode::FAILURE;
    }
    if !kdpp_saved {
        eprintln!("joint scheduling saved no rounds on the k-DPP chain pool");
        return ExitCode::FAILURE;
    }
    match experiments::write_csv(
        &cfg.out_dir,
        "engine.csv",
        &engine::CSV_HEADER,
        &engine::csv_rows(&reports),
    ) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_slq(
    cfg: &RunConfig,
    flags: &HashMap<String, String>,
    reg: Option<&MetricsRegistry>,
) -> ExitCode {
    use gauss_bif::experiments::slq;

    let sizes: Vec<usize> = flags
        .get("sizes")
        .map(|s| parse_list(s))
        .unwrap_or_else(|| vec![32, 48]);
    let reports = slq::run(cfg, &sizes);
    let mut table = gauss_bif::util::bench::Table::new(&[
        "n", "kind", "probes", "estimate", "interval", "exact", "rel err", "tol met", "early",
        "det",
    ]);
    let mut contained = true;
    let mut deterministic = true;
    for r in &reports {
        contained &= r.contained;
        deterministic &= r.deterministic;
        table.row(vec![
            r.n.to_string(),
            r.kind.into(),
            r.probes.to_string(),
            format!("{:.6e}", r.estimate),
            format!("[{:.4e}, {:.4e}]", r.lo, r.hi),
            format!("{:.6e}", r.exact),
            format!("{:.1e}", r.rel_err),
            r.tol_met.to_string(),
            r.retired_early.to_string(),
            r.deterministic.to_string(),
        ]);
    }
    println!("{}", table.render());
    if let Some(reg) = reg {
        reg.set_counter("slq.rows", reports.len() as u64);
        reg.set_counter("slq.contained", reports.iter().filter(|r| r.contained).count() as u64);
        reg.set_counter("slq.tol_met", reports.iter().filter(|r| r.tol_met).count() as u64);
        reg.set_counter(
            "slq.retired_early",
            reports.iter().map(|r| r.retired_early as u64).sum(),
        );
    }
    if !contained {
        eprintln!("an exact spectral sum fell outside its reported combined interval");
        return ExitCode::FAILURE;
    }
    if !deterministic {
        eprintln!("a pinned-seed stochastic answer changed with worker count or sweep mode");
        return ExitCode::FAILURE;
    }
    match experiments::write_csv(
        &cfg.out_dir,
        "slq.csv",
        &slq::CSV_HEADER,
        &slq::csv_rows(&reports),
    ) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("write failed: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_serve(
    cfg: &RunConfig,
    flags: &HashMap<String, String>,
    reg: Option<&MetricsRegistry>,
) -> ExitCode {
    use gauss_bif::coordinator::{BatchPolicy, JudgeService};
    use gauss_bif::datasets::random_spd_exact;
    use gauss_bif::linalg::Cholesky;
    use gauss_bif::util::rng::Rng;

    let n_requests = flags.get("requests").and_then(|s| s.parse().ok()).unwrap_or(200);
    let workers = flags.get("workers").and_then(|s| s.parse().ok()).unwrap_or(2);
    let policy = BatchPolicy {
        max_batch: cfg.block_width.max(1),
        ..BatchPolicy::default()
    };
    let svc = match JudgeService::start(Some(cfg.artifacts_dir.clone()), policy, workers) {
        Ok(svc) => svc,
        Err(e) => {
            eprintln!("invalid batch policy: {e}");
            return ExitCode::from(2);
        }
    };
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    // five shared operators cycled across the request stream; tagging
    // each with its op_key lets the coordinator coalesce co-keyed
    // native-path requests into shared-operator block runs. The oracle
    // factorization is computed once per operator, not per request.
    let ops: Vec<(usize, Vec<f32>, f64, f64, Cholesky)> = [12usize, 16, 24, 31, 48]
        .iter()
        .map(|&n| {
            let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.6, 0.2);
            let ch = Cholesky::factor(&a).unwrap();
            // serialize once: co-keyed requests must carry identical bytes
            let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
            (n, af, l1, ln, ch)
        })
        .collect();
    for i in 0..n_requests {
        let (n, af, l1, ln, ch) = &ops[i % ops.len()];
        let n = *n;
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = ch.bif(&u);
        let t = exact * (0.5 + rng.f64());
        wants.push(t < exact);
        rxs.push(svc.submit(gauss_bif::coordinator::ThresholdRequest {
            a: af.clone(),
            u: u.iter().map(|&x| x as f32).collect(),
            n,
            lam_min: (*l1 * 0.99) as f32,
            lam_max: (*ln * 1.01) as f32,
            t,
            op_key: Some((i % ops.len()) as u64),
            reorth: cfg.reorth,
        }));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv().expect("response");
        if resp.decision == want {
            correct += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} requests in {:.3}s  ({:.0} req/s), {} correct",
        n_requests,
        dt,
        n_requests as f64 / dt,
        correct
    );
    // argmax demo: one raced batch per shared operator ("which of these
    // queries has the largest BIF?"), served by the native scheduler
    let mut races_ok = true;
    for (op_idx, (n, af, l1, ln, ch)) in ops.iter().enumerate() {
        let n = *n;
        let arms: Vec<Vec<f64>> = (0..6)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let mut best: Option<(usize, f64)> = None;
        for (i, u) in arms.iter().enumerate() {
            let v = ch.bif(u);
            if best.map_or(true, |(_, g)| v > g) {
                best = Some((i, v));
            }
        }
        let resp = svc.argmax_blocking(gauss_bif::coordinator::ArgmaxRequest {
            a: af.clone(),
            n,
            lam_min: (*l1 * 0.99) as f32,
            lam_max: (*ln * 1.01) as f32,
            us: arms
                .iter()
                .map(|u| u.iter().map(|&x| x as f32).collect())
                .collect(),
            offsets: vec![0.0; 6],
            negate: false,
            tol_rel: 1e-10,
            prune: cfg.race,
            reorth: cfg.reorth,
            // co-key with the threshold stream on the same operator so
            // the coordinator may fold the race into a shared session
            op_key: Some(op_idx as u64),
        });
        races_ok &= resp.winner == best.map(|(i, _)| i);
    }
    println!("argmax races: {} operators, oracle-correct: {races_ok}", ops.len());
    println!("{}", svc.metrics.summary());
    if let Some(reg) = reg {
        svc.metrics.export_into(reg);
        reg.set_counter("serve.requests", n_requests as u64);
        reg.set_counter("serve.correct", correct as u64);
        reg.set_gauge("serve.requests_per_s", n_requests as f64 / dt);
    }
    svc.shutdown();
    if correct == n_requests && races_ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_info(cfg: &RunConfig) -> ExitCode {
    use gauss_bif::datasets::table1_specs;
    println!("gauss-bif — Gauss quadrature for matrix inverse forms");
    println!("artifacts dir: {}", cfg.artifacts_dir.display());
    match gauss_bif::runtime::GqlRuntime::load(&cfg.artifacts_dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            for a in rt.artifacts() {
                println!(
                    "  {:<20} n={:<4} batch={:<2} iters={:<3} pallas={}",
                    a.meta.name, a.meta.n, a.meta.batch, a.meta.iters, a.meta.pallas
                );
            }
        }
        Err(e) => println!("runtime unavailable: {e}"),
    }
    println!("\nTable-1 dataset substitutes:");
    for s in table1_specs() {
        println!(
            "  {:<10} n={:<6} paper_nnz={:<9} kind={:?}",
            s.name, s.n, s.paper_nnz, s.kind
        );
    }
    ExitCode::SUCCESS
}
