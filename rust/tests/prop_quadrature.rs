//! Cross-module property tests: the quadrature core against the dense
//! substrate, submatrix views, preconditioning and CG — the paper's §4
//! claims exercised end-to-end through the public API.

use gauss_bif::datasets::{random_sparse_spd, random_spd_exact};
use gauss_bif::linalg::Cholesky;
use gauss_bif::quadrature::{
    cg_solve, judge_threshold, Gql, GqlOptions, JacobiPrecond, Reorth,
};
use gauss_bif::sparse::{gershgorin_view, SubmatrixView, SymOp};
use gauss_bif::util::prop::{assert_close, assert_le, forall};
use gauss_bif::util::rng::Rng;

#[test]
fn sparse_and_dense_gql_agree_exactly() {
    // same matrix through CSR and DMat operators ⇒ identical iterates
    forall(15, 0x1001, |rng| {
        let n = 10 + rng.below(40);
        let (a, w) = random_sparse_spd(rng, n, 0.2, 0.05);
        let d = a.to_dense();
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut qs = Gql::new(&a, &u, opts);
        let mut qd = Gql::new(&d, &u, opts);
        for _ in 0..n.min(20) {
            let bs = qs.step();
            let bd = qd.step();
            assert_close(bs.gauss, bd.gauss, 1e-12, 1e-12);
            assert_close(bs.radau_lower, bd.radau_lower, 1e-10, 1e-12);
            assert_close(bs.radau_upper, bd.radau_upper, 1e-10, 1e-12);
            if bs.exact {
                break;
            }
        }
    });
}

#[test]
fn submatrix_view_bounds_match_materialized_submatrix() {
    forall(15, 0x1002, |rng| {
        let n = 20 + rng.below(40);
        let (a, w) = random_sparse_spd(rng, n, 0.25, 0.05);
        let k = 5 + rng.below(n - 6);
        let idx = rng.sample_indices(n, k);
        let view = SubmatrixView::new(&a, &idx);
        let mat = a.principal_submatrix(&idx);
        let u: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi); // valid by interlacing
        let mut qv = Gql::new(&view, &u, opts);
        let mut qm = Gql::new(&mat, &u, opts);
        for _ in 0..k.min(15) {
            let bv = qv.step();
            let bm = qm.step();
            assert_close(bv.gauss, bm.gauss, 1e-12, 1e-12);
            assert_close(bv.lobatto, bm.lobatto, 1e-10, 1e-12);
            if bv.exact {
                break;
            }
        }
    });
}

#[test]
fn judge_on_view_agrees_with_cholesky_truth() {
    forall(20, 0x1003, |rng| {
        let n = 20 + rng.below(30);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let k = 4 + rng.below(n / 2);
        let idx = rng.sample_indices(n, k);
        let v = (0..n).find(|i| !idx.contains(i)).unwrap();
        let view = SubmatrixView::new(&a, &idx);
        let u = view.column_of(v);
        if u.iter().all(|&x| x == 0.0) {
            return; // disconnected: zero BIF, trivially fine
        }
        let exact = Cholesky::factor(&a.principal_submatrix(&idx).to_dense())
            .unwrap()
            .bif(&u);
        let opts = GqlOptions::new(w.lo, w.hi);
        for f in [0.3, 0.8, 1.2, 3.0] {
            let t = exact * f;
            if (t - exact).abs() < 1e-12 {
                continue;
            }
            let (ans, _) = judge_threshold(&view, &u, t, opts);
            assert_eq!(ans, t < exact, "factor {f}");
        }
    });
}

#[test]
fn interlacing_window_is_valid_for_every_submatrix() {
    // Cauchy interlacing: submatrix spectrum ⊂ parent spectrum; the
    // samplers rely on this to reuse one global window.
    forall(15, 0x1004, |rng| {
        let n = 15 + rng.below(30);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let k = 2 + rng.below(n - 2);
        let idx = rng.sample_indices(n, k);
        let view = SubmatrixView::new(&a, &idx);
        let sub_w = gershgorin_view(&view);
        // Gershgorin of the submatrix may be looser than the parent's
        // spectrum, but the actual eigenvalues must respect the parent
        // window — verify via the dense eigensolver.
        let ev = gauss_bif::linalg::sym_eigenvalues(&a.principal_submatrix(&idx).to_dense());
        assert!(w.lo <= ev[0] + 1e-9, "lo {} vs λ1 {}", w.lo, ev[0]);
        assert!(w.hi >= ev[k - 1] - 1e-9);
        let _ = sub_w;
    });
}

#[test]
fn preconditioned_judge_agrees_with_plain_judge() {
    forall(15, 0x1005, |rng| {
        let n = 10 + rng.below(20);
        let (a, _, _) = random_spd_exact(rng, n, 0.5, 0.2);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let pc = JacobiPrecond::new(&a).unwrap();
        let su = pc.scaled_query(&u);
        // window for the transformed op from its Gershgorin via dense copy
        let mut m = gauss_bif::linalg::DMat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let mut col = vec![0.0; n];
            pc.matvec(&e, &mut col);
            for i in 0..n {
                m.set(i, j, col[i]);
            }
        }
        let ev = gauss_bif::linalg::sym_eigenvalues(&m);
        let opts = GqlOptions::new(ev[0] * 0.99, ev[n - 1] * 1.01);
        for f in [0.5, 0.9, 1.1, 2.0] {
            let t = exact * f;
            let (ans, _) = judge_threshold(&pc, &su, t, opts);
            assert_eq!(ans, t < exact, "factor {f}");
        }
    });
}

#[test]
fn thm12_cg_error_equals_gauss_gap() {
    forall(10, 0x1006, |rng| {
        let n = 12 + rng.below(24);
        let (a, l1, ln) = random_spd_exact(rng, n, 0.5, 0.3);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let mut q = Gql::new(&a, &u, GqlOptions::new(l1 * 0.99, ln * 1.01));
        let hist = q.run(n);
        let xstar = Cholesky::factor(&a).unwrap().solve(&u);
        for k in [1usize, 3, 6] {
            if k >= n {
                break;
            }
            let cg = cg_solve(&a, &u, 0.0, k);
            let eps: Vec<f64> = xstar.iter().zip(&cg.x).map(|(s, x)| s - x).collect();
            let mut aeps = vec![0.0; n];
            a.matvec(&eps, &mut aeps);
            let err2: f64 = eps.iter().zip(&aeps).map(|(x, y)| x * y).sum();
            assert_close(exact - hist[k - 1].gauss, err2, 1e-5, 1e-8 * exact.abs());
        }
    });
}

#[test]
fn reorthogonalization_never_worsens_final_accuracy() {
    forall(8, 0x1007, |rng| {
        let n = 20 + rng.below(20);
        let (a, _, ln) = random_spd_exact(rng, n, 1.0, 1e-3);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let base = GqlOptions::new(1e-4, ln * 1.05);
        let mut plain = Gql::new(&a, &u, base);
        let mut reorth = Gql::new(&a, &u, base.with_reorth(Reorth::Full));
        let bp = plain.run(n).last().unwrap().gauss;
        let br = reorth.run(n).last().unwrap().gauss;
        let ep = (bp - exact).abs() / exact;
        let er = (br - exact).abs() / exact;
        assert_le(er, ep * 10.0 + 1e-6, 0.0); // reorth at worst comparable
    });
}
