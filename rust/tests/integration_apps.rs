//! End-to-end application integration on the Table-1 dataset substitutes
//! (scaled): the retrospective variants must make exactly the decisions
//! the exact algorithms make, and the quadrature effort per decision must
//! stay small — the two facts Table 2's speedups rest on.

use gauss_bif::apps::{
    double_greedy, BifStrategy, DgConfig, DppConfig, DppSampler, KdppConfig, KdppSampler,
};
use gauss_bif::datasets::{table1_specs, RIDGE};
use gauss_bif::linalg::Cholesky;
use gauss_bif::sparse::gershgorin_bounds;
use gauss_bif::util::rng::Rng;
use std::sync::Arc;

#[test]
fn dpp_chain_on_rbf_substitute_matches_exact() {
    let mut rng = Rng::new(0x3001);
    let spec = &table1_specs()[0]; // Abalone-like RBF kernel
    let l = Arc::new(spec.build(&mut rng, 32)); // ~130 nodes
    let w = gershgorin_bounds(&l).clamp_lo(RIDGE * 0.5);
    let k = l.n / 3;
    let seed = 0xAB;
    let run = |strategy| {
        let mut r = Rng::new(seed);
        let mut s = DppSampler::new(
            &l,
            DppConfig::new(strategy, w).with_init_size(k),
            &mut r,
        );
        s.run(80, &mut r);
        let mut set = s.current_set().to_vec();
        set.sort_unstable();
        set
    };
    assert_eq!(run(BifStrategy::Exact), run(BifStrategy::Gauss));
}

#[test]
fn kdpp_chain_on_laplacian_substitute_matches_exact() {
    let mut rng = Rng::new(0x3002);
    let spec = &table1_specs()[2]; // GR-like Laplacian
    let l = Arc::new(spec.build(&mut rng, 32));
    let w = gershgorin_bounds(&l).clamp_lo(RIDGE * 0.5);
    let k = (l.n / 4).max(3);
    let seed = 0xCD;
    let run = |strategy| {
        let mut r = Rng::new(seed);
        let mut s = KdppSampler::new(&l, KdppConfig::new(strategy, w, k), &mut r);
        s.run(60, &mut r);
        let mut set = s.current_set().to_vec();
        set.sort_unstable();
        set
    };
    assert_eq!(run(BifStrategy::Exact), run(BifStrategy::Gauss));
}

#[test]
fn dg_on_substitutes_matches_exact_and_has_sane_objective() {
    let mut rng = Rng::new(0x3003);
    for spec in table1_specs().iter().take(3) {
        let l = Arc::new(spec.build(&mut rng, 64));
        let w = gershgorin_bounds(&l).clamp_lo(RIDGE * 0.5);
        let seed = 0xEF ^ spec.n as u64;
        let run = |strategy| {
            let mut r = Rng::new(seed);
            double_greedy(&l, DgConfig::new(strategy, w), &mut r)
        };
        let exact = run(BifStrategy::Exact);
        let gauss = run(BifStrategy::Gauss);
        assert_eq!(exact.chosen, gauss.chosen, "{}", spec.name);
        assert!(gauss.objective.is_finite(), "{}", spec.name);
    }
}

#[test]
fn judge_effort_scales_with_conditioning_not_size() {
    // double the size at fixed density class: average judge iterations
    // should stay in the same ballpark (the paper's core efficiency fact)
    let mut rng = Rng::new(0x3004);
    let mut avg_iters = Vec::new();
    for &n in &[120usize, 240] {
        let (l, w) = gauss_bif::datasets::random_sparse_spd(&mut rng, n, 0.05, 1e-2);
        let l = Arc::new(l);
        let mut r = Rng::new(9);
        let mut s = DppSampler::new(
            &l,
            DppConfig::new(BifStrategy::Gauss, w).with_init_size(n / 3),
            &mut r,
        );
        s.run(100, &mut r);
        avg_iters.push(s.stats.judge_iters_total as f64 / s.stats.decisions.max(1) as f64);
    }
    assert!(
        avg_iters[1] <= avg_iters[0] * 3.0 + 5.0,
        "judge effort exploded with size: {avg_iters:?}"
    );
}

#[test]
fn dg_half_approximation_on_bruteforced_optimum() {
    // Buchbinder et al.: E[F(DG)] ≥ ½ F(OPT) for non-negative submodular
    // F. Build a diagonally-dominant kernel (diag 2, small couplings) so
    // F(S) = log det(L_S) ≥ 0 on every S, brute-force OPT at n = 10, and
    // check the guarantee on the seed-average.
    let n = 10;
    let mut rng = Rng::new(0x3005);
    let mut b = gauss_bif::sparse::CsrBuilder::new(n);
    for i in 0..n {
        b.push(i, i, 2.0);
        for j in (i + 1)..n {
            if rng.bool(0.5) {
                b.push_sym(i, j, 0.08 * rng.normal());
            }
        }
    }
    let l = Arc::new(b.build());
    let w = gershgorin_bounds(&l).clamp_lo(0.5);
    let obj = |idx: &[usize]| -> f64 {
        if idx.is_empty() {
            return 0.0; // log det of the empty matrix
        }
        Cholesky::factor(&l.principal_submatrix(idx).to_dense())
            .unwrap()
            .logdet()
    };
    // brute force OPT over all 2^n subsets
    let mut opt = 0.0f64;
    for mask in 0u32..(1 << n) {
        let idx: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
        opt = opt.max(obj(&idx));
    }
    assert!(opt > 0.0, "test kernel should have positive OPT");
    // average DG value over seeds
    let trials = 30;
    let mut total = 0.0;
    for s in 0..trials {
        let mut r = Rng::new(1000 + s);
        let res = double_greedy(&l, DgConfig::new(BifStrategy::Gauss, w), &mut r);
        total += obj(&res.chosen);
    }
    let mean = total / trials as f64;
    assert!(
        mean >= 0.5 * opt - 0.05 * opt,
        "E[F(DG)] = {mean:.4} < ½·OPT = {:.4}",
        0.5 * opt
    );
}

#[test]
fn dpp_sampler_respects_kernel_structure() {
    // a block-diagonal kernel with one strongly repulsive block: sampled
    // sets should rarely contain two items from the same tight block
    let mut rng = Rng::new(0x3006);
    let n = 30;
    let mut b = gauss_bif::sparse::CsrBuilder::new(n);
    for i in 0..n {
        b.push(i, i, 1.0);
    }
    // items 0..5 nearly identical (high similarity ⇒ strong repulsion)
    for i in 0..5usize {
        for j in (i + 1)..5 {
            b.push_sym(i, j, 0.98);
        }
    }
    let l = Arc::new(b.build().with_diag_shift(1e-3));
    let w = gershgorin_bounds(&l).clamp_lo(5e-4);
    let cfg = DppConfig::new(BifStrategy::Gauss, w).with_init_size(0);
    let mut s = DppSampler::new(&l, cfg, &mut rng);
    s.run(3000, &mut rng);
    let in_block = s.current_set().iter().filter(|&&v| v < 5).count();
    assert!(
        in_block <= 2,
        "repulsive block over-represented: {in_block} of 5 present"
    );
}
