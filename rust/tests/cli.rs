//! End-to-end launcher tests: drive the `gauss-bif` binary the way a user
//! would and check outputs land where the docs say.

use std::path::Path;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gauss-bif"))
}

fn tmp_out(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("gauss_bif_cli_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn fig1_writes_csv_and_reports_convergence() {
    let out = tmp_out("fig1");
    let o = bin()
        .args(["fig1", "--out", out.to_str().unwrap(), "--iters", "30"])
        .output()
        .expect("run fig1");
    assert!(o.status.success(), "stderr: {}", String::from_utf8_lossy(&o.stderr));
    let stdout = String::from_utf8_lossy(&o.stdout);
    assert!(stdout.contains("panel a_tight"), "{stdout}");
    let csv = std::fs::read_to_string(out.join("fig1.csv")).expect("csv");
    assert!(csv.starts_with("panel,iter,gauss"));
    // 3 panels x 30 iters + header
    assert_eq!(csv.lines().count(), 1 + 3 * 30);
}

#[test]
fn rates_passes_and_writes_csv() {
    let out = tmp_out("rates");
    let o = bin()
        .args(["rates", "--out", out.to_str().unwrap(), "--sizes", "24,48"])
        .output()
        .expect("run rates");
    assert!(o.status.success(), "rates reported a theorem violation");
    let stdout = String::from_utf8_lossy(&o.stdout);
    assert_eq!(stdout.matches("[OK]").count(), 2, "{stdout}");
    assert!(out.join("rates.csv").exists());
}

#[test]
fn rates_telemetry_flag_dumps_a_registry_snapshot() {
    let out = tmp_out("telemetry");
    let snap_path = out.join("telemetry.json");
    let o = bin()
        .args([
            "rates",
            "--out",
            out.to_str().unwrap(),
            "--sizes",
            "24",
            "--telemetry",
            snap_path.to_str().unwrap(),
        ])
        .output()
        .expect("run rates with telemetry");
    assert!(o.status.success(), "stderr: {}", String::from_utf8_lossy(&o.stderr));
    let stdout = String::from_utf8_lossy(&o.stdout);
    assert!(stdout.contains("telemetry snapshot:"), "{stdout}");
    let text = std::fs::read_to_string(&snap_path).expect("snapshot written");
    // spot-check the acceptance names: version header, experiment gauges,
    // engine round profile, worker accounting, contraction rates
    for key in [
        "\"version\"",
        "\"rates.n24.rho\"",
        "\"rates.n24.fitted_rate\"",
        "\"engine.profile.schedule_ns\"",
        "\"engine.profile.sweep_ns\"",
        "\"engine.profile.worker_busy_frac\"",
        "\"engine.profile.worker_idle_frac\"",
        "\"engine.profile.step_ns\"",
        "\"run.wall_time_s\"",
    ] {
        assert!(text.contains(key), "snapshot missing {key}:\n{text}");
    }
}

#[test]
fn info_lists_datasets_and_artifacts() {
    let o = bin().arg("info").output().expect("run info");
    assert!(o.status.success());
    let stdout = String::from_utf8_lossy(&o.stdout);
    for name in ["Abalone", "Wine", "GR", "HEP", "Epinions", "Slashdot"] {
        assert!(stdout.contains(name), "missing {name}: {stdout}");
    }
    if Path::new("artifacts/manifest.json").exists() {
        assert!(stdout.contains("PJRT platform"), "{stdout}");
    }
}

#[test]
fn unknown_command_exits_2_with_usage() {
    let o = bin().arg("frobnicate").output().expect("run");
    assert_eq!(o.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&o.stderr).contains("usage:"));
}

#[test]
fn block_sweep_writes_csv_and_stays_exact() {
    let out = tmp_out("block");
    // --scale shrinks the matrix so the sweep stays sub-second
    let o = bin()
        .args([
            "block",
            "--out",
            out.to_str().unwrap(),
            "--scale",
            "40",
            "--ks",
            "2,4",
            "--block-width",
            "4",
        ])
        .output()
        .expect("run block");
    assert!(o.status.success(), "stderr: {}", String::from_utf8_lossy(&o.stderr));
    let csv = std::fs::read_to_string(out.join("block.csv")).expect("csv");
    assert!(csv.starts_with("n,density,nnz,k,width,iters"));
    assert_eq!(csv.lines().count(), 1 + 2, "one row per k");
    // exactness contract: every row reports zero deviation
    for line in csv.lines().skip(1) {
        assert!(line.ends_with("0.0e0"), "max_dev not zero: {line}");
    }
}

#[test]
fn race_sweep_saves_sweeps_and_writes_csv() {
    let out = tmp_out("race");
    // --scale shrinks the kernel; the command exits nonzero if pruning
    // changes a selection or saves no panel sweeps
    let o = bin()
        .args([
            "race",
            "--out",
            out.to_str().unwrap(),
            "--scale",
            "40",
            "--ks",
            "2,4",
            "--block-width",
            "4",
        ])
        .output()
        .expect("run race");
    assert!(o.status.success(), "stderr: {}", String::from_utf8_lossy(&o.stderr));
    let csv = std::fs::read_to_string(out.join("race.csv")).expect("csv");
    assert!(csv.starts_with("n,nnz,k,width,exhaustive_sweeps,prune_sweeps"));
    assert_eq!(csv.lines().count(), 1 + 2, "one row per k");
    for line in csv.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols[9], "true", "selections must be identical: {line}");
    }
}

#[test]
fn invalid_race_flag_exits_2() {
    let o = bin().args(["race", "--race", "sideways"]).output().expect("run");
    assert_eq!(o.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&o.stderr).contains("--race"));
}

#[test]
fn engine_sweep_saves_rounds_and_writes_csv() {
    let out = tmp_out("engine");
    // --scale shrinks every workload; the command exits nonzero if a
    // joint schedule diverges from sequential or saves no rounds
    let o = bin()
        .args(["engine", "--out", out.to_str().unwrap(), "--scale", "40", "--chains", "2"])
        .output()
        .expect("run engine");
    assert!(o.status.success(), "stderr: {}", String::from_utf8_lossy(&o.stderr));
    let csv = std::fs::read_to_string(out.join("engine.csv")).expect("csv");
    assert!(csv.starts_with("n,dg_elements,dg_sequential_rounds"));
    assert_eq!(csv.lines().count(), 1 + 1, "one row per chain count");
    for line in csv.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols[12], "true", "joint workloads must stay identical: {line}");
    }
}

#[test]
fn invalid_engine_knobs_exit_2() {
    // ISSUE 5 satellite: 0/absurd engine knobs are rejected at admission
    // with the typed error's message
    let o = bin()
        .args(["engine", "--engine-lanes", "0"])
        .output()
        .expect("run");
    assert_eq!(o.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&o.stderr).contains("engine_lanes"));
    let o = bin()
        .args(["engine", "--engine-ttl", "0"])
        .output()
        .expect("run");
    assert_eq!(o.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&o.stderr).contains("engine_ttl_rounds"));
}

#[test]
fn config_file_overrides_defaults() {
    let out = tmp_out("cfg");
    std::fs::create_dir_all(&out).unwrap();
    let cfg_path = out.join("run.json");
    std::fs::write(
        &cfg_path,
        format!(r#"{{"seed": 9, "out_dir": "{}"}}"#, out.display()),
    )
    .unwrap();
    let o = bin()
        .args(["rates", "--config", cfg_path.to_str().unwrap(), "--sizes", "24"])
        .output()
        .expect("run with config");
    assert!(o.status.success());
    assert!(out.join("rates.csv").exists(), "out_dir from config respected");
}
