//! Block-engine property tests (ISSUE 1 satellite): `BlockGql` with
//! `block_width = 1` must reproduce scalar `Gql` bound sequences to 1e-12
//! on random SPD matrices, and mixed-convergence runs (lanes exiting at
//! different iterations with queue refill) must match per-query scalar
//! references.

use gauss_bif::datasets::{random_sparse_spd, random_spd_exact};
use gauss_bif::quadrature::block::{run_scalar, BlockGql, StopRule};
use gauss_bif::quadrature::{judge_threshold, Gql, GqlOptions};
use gauss_bif::sparse::{SubmatrixView, SymOp};
use gauss_bif::util::prop::{assert_close, forall};
use std::sync::Arc;

#[test]
fn width_one_reproduces_scalar_gql_sequences_sparse() {
    forall(30, 0xB10C01, |rng| {
        let n = 4 + rng.below(40);
        let (a, w) = random_sparse_spd(rng, n, 0.2, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);

        let mut q = Gql::new(&a, &u, opts);
        let scalar = q.run(n);

        let mut eng = BlockGql::new(&a, opts, 1).record_history(true);
        eng.push(&u, StopRule::Exhaust);
        let block = eng.run_all(&a).pop().expect("one result");

        assert_eq!(scalar.len(), block.history.len(), "sequence lengths differ");
        for (s, b) in scalar.iter().zip(&block.history) {
            assert_eq!(s.iter, b.iter);
            assert_close(s.gauss, b.gauss, 1e-12, 1e-12);
            assert_close(s.radau_lower, b.radau_lower, 1e-12, 1e-12);
            assert_close(s.radau_upper, b.radau_upper, 1e-12, 1e-12);
            assert_close(s.lobatto, b.lobatto, 1e-12, 1e-12);
            assert_eq!(s.exact, b.exact);
        }
    });
}

#[test]
fn width_one_reproduces_scalar_gql_sequences_dense_fallback() {
    // DMat has no specialized matvec_multi: this exercises the SymOp
    // default (de-interleave + scalar matvec) fallback path
    forall(20, 0xB10C02, |rng| {
        let n = 4 + rng.below(24);
        let (a, l1, ln) = random_spd_exact(rng, n, 0.5, 0.2);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(l1 * 0.99, ln * 1.01);

        let mut q = Gql::new(&a, &u, opts);
        let scalar = q.run(n);
        let op: &dyn SymOp = &a;
        let mut eng = BlockGql::new(op, opts, 1).record_history(true);
        eng.push(&u, StopRule::Exhaust);
        let block = eng.run_all(&a).pop().unwrap();

        assert_eq!(scalar.len(), block.history.len());
        for (s, b) in scalar.iter().zip(&block.history) {
            assert_close(s.gauss, b.gauss, 1e-12, 1e-12);
            assert_close(s.radau_lower, b.radau_lower, 1e-12, 1e-12);
            assert_close(s.radau_upper, b.radau_upper, 1e-12, 1e-12);
            assert_close(s.lobatto, b.lobatto, 1e-12, 1e-12);
        }
    });
}

#[test]
fn wide_panels_reproduce_scalar_sequences_exactly() {
    // every lane of a wide panel must still be bit-identical to its own
    // scalar run — the exactness contract of the multi-vector kernels
    forall(15, 0xB10C03, |rng| {
        let n = 8 + rng.below(32);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let m = 2 + rng.below(9);
        let width = 1 + rng.below(m);
        let queries: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let mut eng = BlockGql::new(&a, opts, width).record_history(true);
        for u in &queries {
            eng.push(u, StopRule::Exhaust);
        }
        let results = eng.run_all(&a);
        assert_eq!(results.len(), m);
        for (r, u) in results.iter().zip(&queries) {
            let scalar = run_scalar(&a, u, opts, StopRule::Exhaust, true);
            assert_eq!(scalar.history.len(), r.history.len(), "query {}", r.id);
            for (s, b) in scalar.history.iter().zip(&r.history) {
                assert_eq!(s.gauss.to_bits(), b.gauss.to_bits(), "query {}", r.id);
                assert_eq!(s.radau_upper.to_bits(), b.radau_upper.to_bits());
            }
        }
    });
}

#[test]
fn mixed_convergence_with_queue_refill_matches_scalar_references() {
    // lanes exit at wildly different iterations (hard thresholds decide in
    // 1-2 steps, Exhaust lanes run to n) so the panel constantly refills
    // from the queue; every query must still match its scalar reference
    forall(10, 0xB10C04, |rng| {
        let n = 16 + rng.below(32);
        let (a, w) = random_sparse_spd(rng, n, 0.15, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let m = 8 + rng.below(12);
        let width = 2 + rng.below(4);

        let mut queries: Vec<(Vec<f64>, StopRule)> = Vec::new();
        for i in 0..m {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let stop = match i % 4 {
                0 => {
                    // easy threshold: decided in very few iterations
                    let rough = gauss_bif::quadrature::cg::cg_bif_estimate(&a, &u, 1e-10, 4 * n);
                    StopRule::Threshold(rough * 0.05)
                }
                1 => StopRule::Iters(1 + rng.below(3)),
                2 => StopRule::GapRel(1e-3),
                _ => StopRule::Exhaust,
            };
            queries.push((u, stop));
        }

        let mut eng = BlockGql::new(&a, opts, width);
        for (u, stop) in &queries {
            eng.push(u, *stop);
        }
        let results = eng.run_all(&a);
        assert_eq!(results.len(), m);

        let mut iters_seen = std::collections::BTreeSet::new();
        for (r, (u, stop)) in results.iter().zip(&queries) {
            let scalar = run_scalar(&a, u, opts, *stop, false);
            assert_eq!(r.iters, scalar.iters, "query {} iteration count", r.id);
            assert_eq!(r.decision, scalar.decision, "query {} decision", r.id);
            assert_eq!(
                r.bounds.gauss.to_bits(),
                scalar.bounds.gauss.to_bits(),
                "query {} final gauss value",
                r.id
            );
            iters_seen.insert(r.iters);
        }
        assert!(
            iters_seen.len() > 1,
            "test should exercise lanes exiting at different iterations"
        );
    });
}

#[test]
fn panel_widths_one_through_nine_are_bit_identical_to_scalar_lanes() {
    // ISSUE 8: the widened panel kernels (8-lane chunks + 4-lane
    // half-chunk + scalar tail) must not move a bit for any remainder
    // width 1..=9 — covering the full chunk (8), the half-chunk path
    // (widths 2..=4 and remainders 4..=7), every scalar tail, and one
    // width past the chunk boundary (9). Checked against per-lane scalar
    // matvecs and against the retired fixed-4 reference kernel, on both
    // the CSR spmm and the submatrix-view scatter path (`axpy_lanes`).
    forall(8, 0xB10C06, |rng| {
        let n = 8 + rng.below(48);
        let (a, _w) = random_sparse_spd(rng, n, 0.25, 0.05);
        for b in 1..=9usize {
            let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; n * b];
            a.matvec_multi(&x, &mut y, b);
            let mut y4 = vec![0.0; n * b];
            a.matvec_multi_ref4(&x, &mut y4, b);
            let mut xs = vec![0.0; n];
            let mut ys = vec![0.0; n];
            for l in 0..b {
                for i in 0..n {
                    xs[i] = x[i * b + l];
                }
                a.matvec(&xs, &mut ys);
                for i in 0..n {
                    let want = ys[i].to_bits();
                    assert_eq!(y[i * b + l].to_bits(), want, "csr b={b} lane {l} row {i}");
                    assert_eq!(y4[i * b + l].to_bits(), want, "ref4 b={b} lane {l} row {i}");
                }
            }
        }
        // the submatrix view drives axpy_lanes through the parent-row
        // scatter; a full-size sorted view visits every parent nonzero
        let parent = Arc::new(a);
        let idx: Vec<usize> = (0..n).collect();
        let view = SubmatrixView::new_sorted(&parent, &idx);
        for b in 1..=9usize {
            let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; n * b];
            view.matvec_multi(&x, &mut y, b);
            let mut xs = vec![0.0; n];
            let mut ys = vec![0.0; n];
            for l in 0..b {
                for i in 0..n {
                    xs[i] = x[i * b + l];
                }
                view.matvec(&xs, &mut ys);
                for i in 0..n {
                    assert_eq!(y[i * b + l].to_bits(), ys[i].to_bits(), "view b={b} lane {l}");
                }
            }
        }
    });
}

#[test]
fn block_threshold_decisions_agree_with_scalar_judges() {
    forall(10, 0xB10C05, |rng| {
        let n = 8 + rng.below(24);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = BlockGql::new(&a, opts, 3);
        let mut want = Vec::new();
        for _ in 0..7 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = gauss_bif::quadrature::cg::cg_bif_estimate(&a, &u, 1e-14, 10 * n);
            let t = exact * (0.4 + 1.2 * rng.f64());
            let (dec, stats) = judge_threshold(&a, &u, t, opts);
            eng.push(&u, StopRule::Threshold(t));
            want.push((dec, stats.iters));
        }
        for (r, (dec, iters)) in eng.run_all(&a).iter().zip(&want) {
            assert_eq!(r.decision, Some(*dec), "query {} decision", r.id);
            assert_eq!(r.iters, *iters, "query {} judge iterations", r.id);
        }
    });
}
