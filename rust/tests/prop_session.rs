//! Unified-query-planner invariants (ISSUE 4): a session mixing
//! `Threshold` + `Compare` + `Argmax` (+ `Estimate`) queries on one
//! operator must answer **bit-identically** to the sequential scalar
//! paths it replaced —
//!
//! * threshold answers match the hand-rolled scalar judge loop
//!   (`judge_threshold_src`) in decision, iteration count, *and* outcome,
//! * compare answers match the exact oracle comparison (and the scalar
//!   adaptive ratio judge),
//! * argmax answers match dense-Cholesky oracle argmax and are identical
//!   across `RacePolicy::{Prune,Exhaustive}` under the adaptive prune
//!   margin,
//! * estimate answers are bit-identical to `run_scalar`,
//!
//! including under `Reorth::Full` on an ill-conditioned kernel (tiny
//! ridge ⇒ κ ~ 1e3–1e4, the §5.4 regime).

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::linalg::Cholesky;
use gauss_bif::quadrature::block::{run_scalar, StopRule};
use gauss_bif::quadrature::judge::{judge_ratio, judge_threshold_src, BoundSource};
use gauss_bif::quadrature::query::{Answer, Query, QueryArm, Session};
use gauss_bif::quadrature::race::PRUNE_MARGIN;
use gauss_bif::quadrature::{GqlOptions, RacePolicy, Reorth};
use gauss_bif::sparse::Csr;
use gauss_bif::util::prop::forall;
use gauss_bif::util::rng::Rng;

fn randvec(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Oracle argmax of `offset_i − u_i^T A^{-1} u_i` via dense Cholesky.
fn oracle_argmax(a: &Csr, arms: &[(Vec<f64>, f64)]) -> Option<usize> {
    let ch = Cholesky::factor(&a.to_dense()).expect("SPD");
    let mut best: Option<(usize, f64)> = None;
    for (i, (u, off)) in arms.iter().enumerate() {
        let val = off - ch.bif(u);
        if best.map_or(true, |(_, g)| val > g) {
            best = Some((i, val));
        }
    }
    best.map(|(i, _)| i)
}

/// Drive one mixed session and check every answer against its sequential
/// scalar reference. `opts` carries the reorth knob so the same harness
/// covers the well- and ill-conditioned regimes.
fn check_mixed_session(rng: &mut Rng, l: &Csr, opts: GqlOptions) {
    let n = l.n;
    let ch = Cholesky::factor(&l.to_dense()).expect("SPD");

    // threshold reference: the hand-rolled scalar loop (kept as the
    // ablation entry), NOT judge_threshold — that is itself a session
    // wrapper now, so the comparison would be circular
    let ut = randvec(rng, n);
    let t_thresh = ch.bif(&ut) * (0.4 + rng.f64());
    let (want_t, want_t_stats) = judge_threshold_src(l, &ut, t_thresh, opts, BoundSource::Radau);

    let (cu, cv) = (randvec(rng, n), randvec(rng, n));
    let p = 0.5;
    let truth_cmp = p * ch.bif(&cv) - ch.bif(&cu);
    let t_cmp = truth_cmp + if rng.bool(0.5) { 0.4 } else { -0.4 };
    let (want_c, _) = judge_ratio(l, &cu, &cv, t_cmp, p, opts);
    assert_eq!(want_c, t_cmp < truth_cmp, "scalar ratio judge disagrees with oracle");

    let m = 3 + rng.below(5);
    let arms: Vec<(Vec<f64>, f64)> = (0..m)
        .map(|_| (randvec(rng, n), 2.0 + rng.f64() * 3.0))
        .collect();
    let want_winner = oracle_argmax(l, &arms);

    let ue = randvec(rng, n);
    let est_ref = run_scalar(l, &ue, opts, StopRule::GapRel(1e-8), false);

    let width = 1 + rng.below(8);
    for policy in [RacePolicy::Prune, RacePolicy::Exhaustive] {
        let mut s = Session::new(l, opts, width, policy);
        let q_t = s.submit(Query::Threshold { u: ut.clone(), t: t_thresh });
        let q_c = s.submit(Query::Compare { u: cu.clone(), v: cv.clone(), t: t_cmp, p });
        let q_a = s.submit(Query::Argmax {
            arms: arms
                .iter()
                .map(|(u, off)| QueryArm::gain(u.clone(), StopRule::GapRel(1e-10), *off))
                .collect(),
            floor: None,
        });
        let q_e = s.submit(Query::Estimate { u: ue.clone(), stop: StopRule::GapRel(1e-8) });
        let answers = s.run(l);

        match &answers[q_t] {
            Answer::Threshold { decision, stats } => {
                assert_eq!(*decision, want_t, "threshold decision diverged");
                assert_eq!(stats.iters, want_t_stats.iters, "threshold iters diverged");
                assert_eq!(stats.outcome, want_t_stats.outcome, "threshold outcome diverged");
            }
            other => panic!("wrong answer kind {other:?}"),
        }
        assert_eq!(answers[q_c].decision(), Some(want_c), "compare decision diverged");
        assert_eq!(answers[q_a].winner(), Some(want_winner), "argmax winner diverged");
        match &answers[q_e] {
            Answer::Estimate { bounds, iters, .. } => {
                assert_eq!(*iters, est_ref.iters, "estimate iters diverged");
                assert_eq!(
                    bounds.gauss.to_bits(),
                    est_ref.bounds.gauss.to_bits(),
                    "estimate bounds diverged"
                );
            }
            other => panic!("wrong answer kind {other:?}"),
        }
        assert!(s.prune_margin() >= PRUNE_MARGIN, "margin fell below the fixed floor");
    }
}

#[test]
fn mixed_sessions_answer_identically_to_sequential_scalar_paths() {
    forall(12, 0x5E5510, |rng| {
        let n = 12 + rng.below(24);
        let (l, w) = random_sparse_spd(rng, n, 0.25, 0.05);
        check_mixed_session(rng, &l, GqlOptions::new(w.lo, w.hi));
    });
}

#[test]
fn mixed_sessions_hold_under_full_reorth_on_ill_conditioned_kernels() {
    // tiny ridge ⇒ condition number ~1e3–1e4: the §5.4 regime where plain
    // Lanczos loses bound validity and reorthogonalization matters
    forall(6, 0x5E5511, |rng| {
        let n = 14 + rng.below(14);
        let (l, w) = random_sparse_spd(rng, n, 0.3, 1e-4);
        let opts = GqlOptions::new(w.lo, w.hi).with_reorth(Reorth::Full);
        check_mixed_session(rng, &l, opts);
    });
}

#[test]
fn adaptive_prune_margin_preserves_selection_identity() {
    // the ISSUE 4 satellite: the dominance margin now scales with the
    // observed per-arm bound wiggle; pruning must still select exactly
    // what exhaustive scoring selects, on well- and ill-conditioned
    // kernels alike (the latter is where wiggle actually appears)
    forall(10, 0x5E5512, |rng| {
        let n = 16 + rng.below(24);
        let ridge = if rng.bool(0.5) { 0.05 } else { 1e-4 };
        let (l, w) = random_sparse_spd(rng, n, 0.25, ridge);
        // the ill-conditioned arm keeps §5.4 reorthogonalization so its
        // brackets stay valid — the wiggle the margin adapts to is the
        // residual floating-point noise, not wholesale bound breakdown
        let opts = if ridge < 1e-3 {
            GqlOptions::new(w.lo, w.hi).with_reorth(Reorth::Full)
        } else {
            GqlOptions::new(w.lo, w.hi)
        };
        let m = 4 + rng.below(6);
        let arms: Vec<(Vec<f64>, f64)> = (0..m)
            .map(|_| (randvec(rng, n), 1.0 + rng.f64() * 4.0))
            .collect();
        let width = 1 + rng.below(m);
        let run = |policy| {
            let mut s = Session::new(&l, opts, width, policy);
            let qid = s.submit(Query::Argmax {
                arms: arms
                    .iter()
                    .map(|(u, off)| QueryArm::gain(u.clone(), StopRule::GapRel(1e-10), *off))
                    .collect(),
                floor: None,
            });
            let winner = s.run(&l)[qid].winner().expect("argmax answer");
            (winner, s.sweeps(), s.prune_margin())
        };
        let (w_ex, sweeps_ex, _) = run(RacePolicy::Exhaustive);
        let (w_pr, sweeps_pr, margin) = run(RacePolicy::Prune);
        assert_eq!(w_ex, w_pr, "adaptive margin changed the selection");
        assert_eq!(w_ex, oracle_argmax(&l, &arms), "wrong argmax");
        assert!(sweeps_pr <= sweeps_ex, "pruning added sweeps");
        assert!(margin >= PRUNE_MARGIN, "margin fell below the fixed floor");
    });
}

#[test]
fn session_queries_resolve_incrementally_under_step() {
    // drive a session sweep-by-sweep: thresholds with far-away cutoffs
    // resolve first while the estimate keeps refining — the scheduling
    // behavior the coordinator's mixed serving relies on
    let mut rng = Rng::new(0x5E5513);
    let n = 32;
    let (l, w) = random_sparse_spd(&mut rng, n, 0.2, 0.05);
    let opts = GqlOptions::new(w.lo, w.hi);
    let ch = Cholesky::factor(&l.to_dense()).unwrap();
    let u = randvec(&mut rng, n);
    let easy_t = ch.bif(&u) * 0.01; // decided in very few iterations
    let mut s = Session::new(&l, opts, 4, RacePolicy::Prune);
    let q_easy = s.submit(Query::Threshold { u: u.clone(), t: easy_t });
    let q_est = s.submit(Query::Estimate { u, stop: StopRule::Exhaust });
    let mut easy_resolved_at = None;
    let mut steps = 0usize;
    while s.step(&l) {
        steps += 1;
        if easy_resolved_at.is_none() && s.is_resolved(q_easy) {
            easy_resolved_at = Some(steps);
        }
    }
    assert!(s.is_resolved(q_est));
    let at = easy_resolved_at.expect("easy threshold resolved");
    assert!(
        at < steps,
        "easy threshold should resolve before the exhaustive estimate ({at} vs {steps})"
    );
    assert_eq!(s.run(&l).len(), 2);
}
