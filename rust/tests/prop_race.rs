//! Racing-scheduler invariants (ISSUE 3): interval-dominance pruning must
//! never change a decision — only the amount of quadrature spent on it.
//!
//! * greedy MAP: `RacePolicy::Prune` and `RacePolicy::Exhaustive` select
//!   identical subsets on random SPD kernels, across panel widths, and
//!   under `Reorth::Full` on an ill-conditioned kernel;
//! * double greedy: identical chosen sets across policies;
//! * regression: on a kernel with a clear gain gap, pruning saves a
//!   strictly positive number of `matvec_multi` panel sweeps;
//! * engine: lanes evicted mid-run never disturb the survivors' results.

use gauss_bif::apps::dpp::{greedy_map_stats, GreedyConfig};
use gauss_bif::apps::{double_greedy, BifStrategy, DgConfig};
use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::experiments::race::gapped_kernel;
use gauss_bif::quadrature::block::{BlockGql, RetireReason, StopRule};
use gauss_bif::quadrature::{GqlOptions, RacePolicy, Reorth};
use gauss_bif::util::prop::forall;
use gauss_bif::util::rng::Rng;
use std::sync::Arc;

#[test]
fn greedy_prune_and_exhaustive_select_identical_sets() {
    forall(10, 0x9A5E01, |rng| {
        let n = 20 + rng.below(36);
        let (l, w) = random_sparse_spd(rng, n, 0.15, 0.05);
        let l = Arc::new(l);
        let k = 3 + rng.below(8);
        for width in [1usize, 4, 9] {
            let base = GreedyConfig::new(w, k).with_block_width(width);
            let (ex, ex_stats) = greedy_map_stats(&l, &base.with_race(RacePolicy::Exhaustive));
            let (pr, pr_stats) = greedy_map_stats(&l, &base.with_race(RacePolicy::Prune));
            assert_eq!(ex, pr, "selection changed at width {width}");
            assert!(
                pr_stats.sweeps <= ex_stats.sweeps,
                "pruning spent more sweeps at width {width} ({} vs {})",
                pr_stats.sweeps,
                ex_stats.sweeps
            );
        }
    });
}

#[test]
fn greedy_policies_agree_under_full_reorth_on_ill_conditioned_kernels() {
    // tiny ridge ⇒ condition number ~1e3–1e4: the regime where plain
    // Lanczos loses bound validity and §5.4 reorthogonalization matters
    forall(5, 0x9A5E02, |rng| {
        let n = 18 + rng.below(14);
        let (l, w) = random_sparse_spd(rng, n, 0.3, 1e-4);
        let l = Arc::new(l);
        let k = 3 + rng.below(4);
        let base = GreedyConfig::new(w, k)
            .with_block_width(1 + rng.below(6))
            .with_reorth(Reorth::Full);
        let (ex, _) = greedy_map_stats(&l, &base.with_race(RacePolicy::Exhaustive));
        let (pr, _) = greedy_map_stats(&l, &base.with_race(RacePolicy::Prune));
        assert_eq!(ex, pr, "reorth selection changed under pruning");
    });
}

#[test]
fn double_greedy_policies_choose_identical_sets() {
    forall(8, 0x9A5E03, |rng| {
        let n = 16 + rng.below(24);
        let (l, w) = random_sparse_spd(rng, n, 0.2, 0.05);
        let l = Arc::new(l);
        let seed = rng.next_u64();
        let run = |race| {
            let mut r = Rng::new(seed);
            double_greedy(
                &l,
                DgConfig::new(BifStrategy::Gauss, w).with_race(race),
                &mut r,
            )
        };
        let pr = run(RacePolicy::Prune);
        let ex = run(RacePolicy::Exhaustive);
        assert_eq!(pr.chosen, ex.chosen);
        assert!(pr.judge_iters_total <= ex.judge_iters_total);
    });
}

#[test]
fn regression_gapped_kernel_saves_sweeps() {
    // pinned: a kernel with a clear gain gap must show sweeps-saved > 0
    // (the ISSUE 3 acceptance criterion, in test form)
    let mut rng = Rng::new(0x9A5E04);
    let n = 120;
    let (l, w) = gapped_kernel(&mut rng, n, 0.03, 10, 50.0);
    let l = Arc::new(l);
    let base = GreedyConfig::new(w, 5).with_block_width(8);
    let (ex, ex_stats) = greedy_map_stats(&l, &base.with_race(RacePolicy::Exhaustive));
    let (pr, pr_stats) = greedy_map_stats(&l, &base.with_race(RacePolicy::Prune));
    assert_eq!(ex, pr, "gapped selection changed");
    assert!(
        pr_stats.sweeps < ex_stats.sweeps,
        "no sweeps saved on a gapped kernel (prune {} vs exhaustive {})",
        pr_stats.sweeps,
        ex_stats.sweeps
    );
    assert!(pr_stats.pruned > 0, "no candidate was ever pruned");
    // (decided_early stays 0 here by design: the working sets are tiny,
    // so the winner reaches Krylov exhaustion on schedule and the savings
    // come entirely from pruning its rivals)
}

#[test]
fn eviction_never_disturbs_surviving_lanes() {
    // retire lanes mid-run at random: every survivor's result must stay
    // bit-identical to an undisturbed run — the engine-level fact the
    // race's selection-identity guarantee rests on
    forall(10, 0x9A5E05, |rng| {
        let n = 12 + rng.below(24);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let m = 4 + rng.below(5);
        let width = 2 + rng.below(3);
        let queries: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let undisturbed: Vec<_> = {
            let mut eng = BlockGql::new(&a, opts, width);
            for u in &queries {
                eng.push(u, StopRule::Exhaust);
            }
            eng.run_all(&a)
        };
        let victims: Vec<usize> = (0..m).filter(|_| rng.bool(0.4)).collect();
        let mut eng = BlockGql::new(&a, opts, width);
        for u in &queries {
            eng.push(u, StopRule::Exhaust);
        }
        let mut steps = 0usize;
        let mut evicted: Vec<usize> = Vec::new();
        loop {
            if !eng.step_panel(&a) {
                break;
            }
            steps += 1;
            if steps == 2 {
                for &v in &victims {
                    // a victim that already finished (early breakdown)
                    // cannot be retired — it keeps its result
                    if eng.retire(v, RetireReason::Dominated) {
                        evicted.push(v);
                    }
                }
            }
        }
        let survivors = eng.take_done();
        for s in &survivors {
            assert!(!evicted.contains(&s.id), "retired lane produced a result");
            let reference = undisturbed
                .iter()
                .find(|r| r.id == s.id)
                .expect("survivor in reference run");
            assert_eq!(s.iters, reference.iters, "query {}", s.id);
            assert_eq!(
                s.bounds.gauss.to_bits(),
                reference.bounds.gauss.to_bits(),
                "query {}",
                s.id
            );
            assert_eq!(
                s.bounds.radau_upper.to_bits(),
                reference.bounds.radau_upper.to_bits()
            );
        }
        assert_eq!(survivors.len() + evicted.len(), m);
    });
}

#[test]
fn suspended_lanes_resume_into_identical_results() {
    // suspend → let the panel drain → resume: final bounds bit-identical
    forall(8, 0x9A5E06, |rng| {
        let n = 10 + rng.below(20);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let u0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let u1: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let reference = {
            let mut eng = BlockGql::new(&a, opts, 2);
            eng.push(&u0, StopRule::Exhaust);
            eng.run_all(&a).pop().unwrap()
        };
        let mut eng = BlockGql::new(&a, opts, 2);
        let id0 = eng.push(&u0, StopRule::Exhaust);
        eng.push(&u1, StopRule::Exhaust);
        assert!(eng.step_panel(&a));
        assert!(eng.suspend(id0));
        while eng.step_panel(&a) {}
        assert!(eng.resume(id0));
        while eng.step_panel(&a) {}
        let out = eng.take_done();
        let r0 = out.iter().find(|r| r.id == id0).expect("resumed lane");
        assert_eq!(r0.iters, reference.iters);
        assert_eq!(r0.bounds.gauss.to_bits(), reference.bounds.gauss.to_bits());
        assert_eq!(
            r0.bounds.radau_lower.to_bits(),
            reference.bounds.radau_lower.to_bits()
        );
    });
}
