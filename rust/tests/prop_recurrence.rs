//! ISSUE 2 regression suite for the recurrence extraction.
//!
//! 1. **Golden-sequence regression**: `RefGql` below is a frozen, verbatim
//!    transcription of the scalar engine *before* the Sherman–Morrison
//!    recurrence and Radau/Lobatto corrections moved into
//!    `quadrature::recurrence` (seed `rust/src/quadrature/gql.rs` @
//!    b88f303, `step()` lines 221-298). The refactored `Gql` must
//!    reproduce its bound sequence **bit-for-bit**, with and without
//!    reorthogonalization — pinning the extraction to the exact
//!    floating-point op sequence rather than to a tolerance.
//! 2. **Block reorthogonalization**: `BlockGql` lanes with `Reorth::Full`
//!    are bit-identical to scalar `Reorth::Full` runs at width 1 *and* in
//!    wide panels, on well- and ill-conditioned operators.
//! 3. **Ill-conditioned sandwich** (mirrors the scalar
//!    `reorthogonalization_stays_valid_longer`): reorthogonalized block
//!    lanes on a dense λ₁ ≈ 1e-4 operator keep valid brackets and land on
//!    the exact BIF at exhaustion.

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::linalg::{sym_eigenvalues, Cholesky, DMat};
use gauss_bif::quadrature::block::{run_scalar, BlockGql, StopRule};
use gauss_bif::quadrature::{Gql, GqlOptions, Reorth};
use gauss_bif::sparse::SymOp;
use gauss_bif::util::prop::{assert_close, forall};
use gauss_bif::util::rng::Rng;

/// One pre-extraction iteration's outputs (the four bound values plus the
/// breakdown flag — the seed engine's `exact` at emission time).
struct RefBounds {
    iter: usize,
    gauss: f64,
    radau_lower: f64,
    radau_upper: f64,
    lobatto: f64,
    breakdown: bool,
}

/// Frozen pre-extraction scalar engine (seed transcription; do not
/// "clean up" — its literal op sequence is the regression target).
struct RefGql<'a> {
    op: &'a dyn SymOp,
    n: usize,
    unorm2: f64,
    lam_min: f64,
    lam_max: f64,
    reorth_full: bool,
    v_prev: Vec<f64>,
    v_curr: Vec<f64>,
    w: Vec<f64>,
    beta_prev: f64,
    g: f64,
    c: f64,
    delta: f64,
    d_lr: f64,
    d_rr: f64,
    iter: usize,
    exhausted: bool,
    basis: Vec<Vec<f64>>,
}

const REF_BREAKDOWN_TOL: f64 = 1e-13;

impl<'a> RefGql<'a> {
    fn new(op: &'a dyn SymOp, u: &[f64], lam_min: f64, lam_max: f64, reorth_full: bool) -> Self {
        let n = op.dim();
        let unorm2: f64 = u.iter().map(|x| x * x).sum();
        let inv_norm = 1.0 / unorm2.sqrt();
        let v_curr: Vec<f64> = u.iter().map(|x| x * inv_norm).collect();
        RefGql {
            op,
            n,
            unorm2,
            lam_min,
            lam_max,
            reorth_full,
            v_prev: vec![0.0; n],
            v_curr,
            w: vec![0.0; n],
            beta_prev: 0.0,
            g: 0.0,
            c: 1.0,
            delta: 0.0,
            d_lr: 0.0,
            d_rr: 0.0,
            iter: 0,
            exhausted: false,
            basis: Vec::new(),
        }
    }

    fn corrections(&self, beta: f64) -> (f64, f64, f64) {
        let (lam_min, lam_max) = (self.lam_min, self.lam_max);
        let beta2 = beta * beta;
        let a_lr = lam_min + beta2 / self.d_lr;
        let a_rr = lam_max + beta2 / self.d_rr;
        let denom = self.d_rr - self.d_lr;
        let b_lo2 = (lam_max - lam_min) * self.d_lr * self.d_rr / denom;
        let a_lo = (lam_max * self.d_rr - lam_min * self.d_lr) / denom;
        let c2 = self.c * self.c;
        let k = self.unorm2 * c2 / self.delta;
        let g_rr = self.g + k * beta2 / (a_rr * self.delta - beta2);
        let g_lr = self.g + k * beta2 / (a_lr * self.delta - beta2);
        let g_lo = self.g + k * b_lo2 / (a_lo * self.delta - b_lo2);
        (g_rr, g_lr, g_lo)
    }

    fn step(&mut self) -> RefBounds {
        self.iter += 1;
        self.op.matvec(&self.v_curr, &mut self.w);
        let alpha: f64 = self.v_curr.iter().zip(&self.w).map(|(a, b)| a * b).sum();
        for ((wi, &vc), &vp) in self.w.iter_mut().zip(&self.v_curr).zip(&self.v_prev) {
            *wi -= alpha * vc + self.beta_prev * vp;
        }
        if self.reorth_full {
            if self.basis.is_empty() {
                self.basis.push(self.v_curr.clone());
            }
            for _pass in 0..2 {
                for q in &self.basis {
                    let proj: f64 = q.iter().zip(&self.w).map(|(a, b)| a * b).sum();
                    for (wi, &qi) in self.w.iter_mut().zip(q) {
                        *wi -= proj * qi;
                    }
                }
            }
        }
        let beta = self.w.iter().map(|x| x * x).sum::<f64>().sqrt();

        if self.iter == 1 {
            self.g = self.unorm2 / alpha;
            self.c = 1.0;
            self.delta = alpha;
            self.d_lr = alpha - self.lam_min;
            self.d_rr = alpha - self.lam_max;
        } else {
            let bp2 = self.beta_prev * self.beta_prev;
            self.g += self.unorm2 * bp2 * self.c * self.c
                / (self.delta * (alpha * self.delta - bp2));
            self.c *= self.beta_prev / self.delta;
            let delta_new = alpha - bp2 / self.delta;
            self.d_lr = alpha - self.lam_min - bp2 / self.d_lr;
            self.d_rr = alpha - self.lam_max - bp2 / self.d_rr;
            self.delta = delta_new;
        }

        let breakdown = !(beta > REF_BREAKDOWN_TOL * alpha.abs().max(1.0));
        let out = if breakdown {
            self.exhausted = true;
            RefBounds {
                iter: self.iter,
                gauss: self.g,
                radau_lower: self.g,
                radau_upper: self.g,
                lobatto: self.g,
                breakdown: true,
            }
        } else {
            let (g_rr, g_lr, g_lo) = self.corrections(beta);
            RefBounds {
                iter: self.iter,
                gauss: self.g,
                radau_lower: g_rr,
                radau_upper: g_lr,
                lobatto: g_lo,
                breakdown: false,
            }
        };
        if !breakdown {
            let inv_beta = 1.0 / beta;
            std::mem::swap(&mut self.v_prev, &mut self.v_curr);
            for (vc, &wi) in self.v_curr.iter_mut().zip(&self.w) {
                *vc = wi * inv_beta;
            }
            self.beta_prev = beta;
            if self.reorth_full {
                self.basis.push(self.v_curr.clone());
            }
        }
        if self.iter >= self.n {
            self.exhausted = true;
        }
        out
    }
}

#[test]
fn golden_scalar_sequence_is_preserved_by_the_extraction() {
    forall(20, 0x60A11, |rng| {
        let n = 4 + rng.below(28);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        for reorth in [Reorth::None, Reorth::Full] {
            let opts = GqlOptions::new(w.lo, w.hi).with_reorth(reorth);
            let mut q = Gql::new(&a, &u, opts);
            let mut r = RefGql::new(&a, &u, w.lo, w.hi, reorth == Reorth::Full);
            loop {
                let want = r.step();
                let got = q.step();
                assert_eq!(got.iter, want.iter);
                assert_eq!(got.gauss.to_bits(), want.gauss.to_bits(), "gauss @ {}", want.iter);
                assert_eq!(got.radau_lower.to_bits(), want.radau_lower.to_bits());
                assert_eq!(got.radau_upper.to_bits(), want.radau_upper.to_bits());
                assert_eq!(got.lobatto.to_bits(), want.lobatto.to_bits());
                // the exactness *flag* gained the iter == n case (ISSUE 2
                // satellite); the values above stay pinned regardless
                assert_eq!(got.exact, want.breakdown || want.iter >= n);
                if r.exhausted {
                    assert!(q.is_exhausted());
                    break;
                }
            }
        }
    });
}

#[test]
fn width_one_reorth_block_is_bit_identical_to_scalar_reorth() {
    forall(15, 0x60A22, |rng| {
        let n = 6 + rng.below(30);
        let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi).with_reorth(Reorth::Full);

        let mut q = Gql::new(&a, &u, opts);
        let scalar = q.run(n);

        let mut eng = BlockGql::new(&a, opts, 1).record_history(true);
        eng.push(&u, StopRule::Exhaust);
        let block = eng.run_all(&a).pop().expect("one result");

        assert_eq!(scalar.len(), block.history.len(), "sequence lengths differ");
        for (s, b) in scalar.iter().zip(&block.history) {
            assert_eq!(s.iter, b.iter);
            assert_eq!(s.gauss.to_bits(), b.gauss.to_bits());
            assert_eq!(s.radau_lower.to_bits(), b.radau_lower.to_bits());
            assert_eq!(s.radau_upper.to_bits(), b.radau_upper.to_bits());
            assert_eq!(s.lobatto.to_bits(), b.lobatto.to_bits());
            assert_eq!(s.exact, b.exact);
        }
    });
}

/// Paper §4.4-style dense shifted-SPD generator (density 1): returns the
/// matrix with λ₁ = `lam1` plus its λ_N.
fn dense_shifted_spd(rng: &mut Rng, n: usize, lam1: f64) -> (DMat, f64) {
    let mut a = DMat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal();
            a.set(i, j, v);
            a.set(j, i, v);
        }
    }
    let ev = sym_eigenvalues(&a);
    a.shift_diag(lam1 - ev[0]);
    (a, ev[n - 1] - ev[0] + lam1)
}

#[test]
fn ill_conditioned_block_lanes_sandwich_with_reorth() {
    // the §5.4 regime the block engine was previously locked out of:
    // dense, λ₁ ≈ 1e-4. With Reorth::Full every lane must keep a valid
    // bracket throughout and land tightly on the exact BIF at exhaustion;
    // per-lane results must also be bit-identical to scalar reorth runs
    // (the exactness contract, now including ill-conditioned operators).
    let mut rng = Rng::new(0x60A33);
    let n = 40;
    let (a, ln) = dense_shifted_spd(&mut rng, n, 1e-4);
    let ch = Cholesky::factor(&a).unwrap();
    let queries: Vec<Vec<f64>> = (0..5)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect();
    let exact: Vec<f64> = queries.iter().map(|u| ch.bif(u)).collect();
    let opts = GqlOptions::new(1e-5, ln * 1.1).with_reorth(Reorth::Full);

    // width 3 < 5 queries: exercises refill/compaction with reorth lanes
    let mut eng = BlockGql::new(&a, opts, 3).record_history(true);
    for u in &queries {
        eng.push(u, StopRule::Exhaust);
    }
    let results = eng.run_all(&a);
    assert_eq!(results.len(), queries.len());
    for ((r, u), e) in results.iter().zip(&queries).zip(&exact) {
        // tight at exhaustion (mirror of reorthogonalization_stays_valid_longer)
        assert_close(r.bounds.gauss, *e, 1e-5, 1e-8);
        // valid (loosely-toleranced) sandwich at every iteration
        let tol = 1e-3 * e.abs().max(1e-8);
        for b in &r.history {
            assert!(b.lower() <= *e + tol, "lane {} iter {}: lower bound invalid", r.id, b.iter);
            assert!(b.upper() >= *e - tol, "lane {} iter {}: upper bound invalid", r.id, b.iter);
        }
        // bit-identical to the scalar reorth path, ill-conditioned included
        let scalar = run_scalar(&a, u, opts, StopRule::Exhaust, false);
        assert_eq!(r.bounds.gauss.to_bits(), scalar.bounds.gauss.to_bits(), "lane {}", r.id);
        assert_eq!(r.iters, scalar.iters, "lane {}", r.id);
    }
}
