//! Integration tests for the PJRT runtime + coordinator against the AOT
//! artifacts. These need `make artifacts`; when artifacts are absent the
//! tests print a notice and pass vacuously (the Makefile's `test` target
//! always builds artifacts first, so CI-style runs exercise everything).

use gauss_bif::coordinator::{BatchPolicy, JudgeService, RoutePath, ThresholdRequest};
use gauss_bif::datasets::random_spd_exact;
use gauss_bif::linalg::Cholesky;
use gauss_bif::quadrature::{Gql, GqlOptions};
use gauss_bif::runtime::GqlRuntime;
use gauss_bif::util::rng::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping");
        None
    }
}

fn to_f32_rowmajor(a: &gauss_bif::linalg::DMat) -> Vec<f32> {
    let n = a.nrows;
    (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect()
}

#[test]
fn pjrt_bounds_match_native_gql() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = GqlRuntime::load(&dir).expect("load artifacts");
    let mut rng = Rng::new(0x2001);
    for &n in &[8usize, 16, 24, 32] {
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.7, 0.3);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let hist = rt
            .gql_bounds(
                &to_f32_rowmajor(&a),
                &u.iter().map(|&x| x as f32).collect::<Vec<_>>(),
                n,
                (l1 * 0.99) as f32,
                (ln * 1.01) as f32,
            )
            .expect("execute");
        // native f64 reference
        let mut q = Gql::new(&a, &u, GqlOptions::new(l1 * 0.99, ln * 1.01));
        for i in 0..hist.len().min(n.saturating_sub(2)) {
            let native = q.step();
            if native.exact {
                break;
            }
            let b = hist.at(i);
            // f32 artifact vs f64 native: loose-ish tolerances
            let tol = 2e-2 * native.gauss.abs().max(1e-3);
            assert!(
                (b.gauss - native.gauss).abs() <= tol,
                "n={n} iter={i}: pjrt {} vs native {}",
                b.gauss,
                native.gauss
            );
            assert!(
                (b.radau_lower - native.radau_lower).abs() <= tol,
                "n={n} iter={i} radau_lower"
            );
        }
    }
}

#[test]
fn pjrt_bounds_sandwich_truth() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = GqlRuntime::load(&dir).expect("load artifacts");
    let mut rng = Rng::new(0x2002);
    let n = 20;
    let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.8, 0.3);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = Cholesky::factor(&a).unwrap().bif(&u);
    let hist = rt
        .gql_bounds(
            &to_f32_rowmajor(&a),
            &u.iter().map(|&x| x as f32).collect::<Vec<_>>(),
            n,
            (l1 * 0.99) as f32,
            (ln * 1.01) as f32,
        )
        .unwrap();
    let tol = 5e-3 * exact.abs();
    for i in 0..hist.len() {
        let b = hist.at(i);
        assert!(b.radau_lower <= exact + tol, "iter {i}");
        assert!(b.radau_upper >= exact - tol, "iter {i}");
    }
}

#[test]
fn identity_padding_invariance_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = GqlRuntime::load(&dir).expect("load artifacts");
    let mut rng = Rng::new(0x2003);
    let n = 10; // pads into the 16-bucket
    let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.9, 0.4);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let af: Vec<f32> = to_f32_rowmajor(&a);
    let uf: Vec<f32> = u.iter().map(|&x| x as f32).collect();
    let lo = (l1 * 0.99) as f32;
    let hi = (ln * 1.01) as f32;
    // padded into 16 via the runtime helper
    let h16 = rt.gql_bounds(&af, &uf, n, lo, hi).unwrap();
    // padded twice as far (manually into 32) must give the same bounds
    let (a32, u32) = GqlRuntime::pad_query(&af, &uf, n, 32);
    let art32 = rt
        .artifacts()
        .iter()
        .find(|x| x.meta.n == 32 && x.meta.batch == 1)
        .expect("32-bucket");
    let h32 = art32.execute(&a32, &u32, lo, hi).unwrap();
    for i in 0..h16.len().min(h32.len()).min(n) {
        let (b16, b32) = (h16.at(i), h32.at(i));
        assert!(
            (b16.gauss - b32.gauss).abs() <= 1e-4 * b16.gauss.abs().max(1e-3),
            "iter {i}: {} vs {}",
            b16.gauss,
            b32.gauss
        );
    }
}

#[test]
fn batched_artifact_matches_single_lane_for_each_query() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = GqlRuntime::load(&dir).expect("load artifacts");
    let Some(art) = rt
        .artifacts()
        .iter()
        .find(|a| a.meta.batch > 1 && a.meta.n == 32)
    else {
        eprintln!("no batched 32-bucket; skipping");
        return;
    };
    let (n, b) = (art.meta.n, art.meta.batch);
    let mut rng = Rng::new(0x2004);
    let mut a_all = Vec::new();
    let mut u_all = Vec::new();
    let mut lo_all = Vec::new();
    let mut hi_all = Vec::new();
    let mut singles = Vec::new();
    for _ in 0..b {
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.7, 0.3);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let af = to_f32_rowmajor(&a);
        let uf: Vec<f32> = u.iter().map(|&x| x as f32).collect();
        let lo = (l1 * 0.99) as f32;
        let hi = (ln * 1.01) as f32;
        singles.push(rt.gql_bounds(&af, &uf, n, lo, hi).unwrap());
        a_all.extend_from_slice(&af);
        u_all.extend_from_slice(&uf);
        lo_all.push(lo);
        hi_all.push(hi);
    }
    let batched = art.execute_batch(&a_all, &u_all, &lo_all, &hi_all).unwrap();
    assert_eq!(batched.len(), b);
    for (lane, single) in batched.iter().zip(&singles) {
        for i in 0..lane.len().min(single.len()).min(16) {
            let (bb, sb) = (lane.at(i), single.at(i));
            assert!(
                (bb.gauss - sb.gauss).abs() <= 1e-3 * sb.gauss.abs().max(1e-3),
                "iter {i}: batched {} vs single {}",
                bb.gauss,
                sb.gauss
            );
        }
    }
}

#[test]
fn service_with_artifacts_is_oracle_correct_and_uses_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = JudgeService::start(Some(dir), BatchPolicy::default(), 2).expect("valid policy");
    let mut rng = Rng::new(0x2005);
    let mut pjrt_seen = false;
    for i in 0..40 {
        let n = [10, 16, 30, 60][i % 4];
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.7, 0.3);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let t = exact * (0.5 + rng.f64());
        let resp = svc.judge_blocking(ThresholdRequest {
            a: to_f32_rowmajor(&a),
            u: u.iter().map(|&x| x as f32).collect(),
            n,
            lam_min: (l1 * 0.99) as f32,
            lam_max: (ln * 1.01) as f32,
            t,
            op_key: None,
            reorth: false,
        });
        assert_eq!(resp.decision, t < exact, "i={i} n={n}");
        if matches!(resp.path, RoutePath::Pjrt { .. }) {
            pjrt_seen = true;
        }
    }
    assert!(pjrt_seen, "expected at least one PJRT-served request");
    svc.shutdown();
}
