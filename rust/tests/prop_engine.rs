//! Multi-operator streaming-engine invariants (ISSUE 5): the engine is a
//! **scheduler, not a numeric path** — its answers must be bit-identical
//! to sequential per-operator `Session` runs, in decisions, estimates,
//! and per-lane iteration counts. Asserted here across:
//!
//! * mixed query kinds (threshold + compare + estimate + argmax) over
//!   several operators at once,
//! * `Reorth::Full` on ill-conditioned kernels (tiny ridge, the §5.4
//!   regime),
//! * streaming submission landing mid-flight,
//! * query-level suspend/resume under a global lane budget of 1,
//! * parallel sweeps with ≥ 2 workers, in both [`SweepMode`]s (the
//!   ISSUE 8 work-stealing fan-out and the static chunk baseline),
//!   profiled and unprofiled, across random worker counts, skewed
//!   operator sizes, and mid-flight submissions,
//! * the query-lifecycle flight recorder on vs. off (ISSUE 10): event
//!   emission hooks admission/schedule/harvest only, never the sweep.

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::metrics::{MetricValue, MetricsRegistry};
use gauss_bif::quadrature::block::{run_scalar, StopRule};
use gauss_bif::quadrature::engine::{
    Engine, EngineConfig, OpKey, SubmitError, SweepMode, Ticket, TicketError,
};
use gauss_bif::quadrature::query::{Answer, Query, QueryArm, Session};
use gauss_bif::quadrature::race::RacePolicy;
use gauss_bif::quadrature::{Bounds, GqlOptions, Reorth};
use gauss_bif::sparse::Csr;
use gauss_bif::util::prop::forall;
use gauss_bif::util::rng::Rng;
use std::sync::Arc;

fn randvec(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// A mixed per-operator workload: 2 thresholds, 1 compare, 1 estimate,
/// and a 3-arm argmax — 8 lanes total, so a width-8 session admits every
/// lane of an active query at once (the lockstep shape the strict
/// iteration-count identity is stated for).
const PER_OP_LANES: usize = 8;

fn mixed_queries(rng: &mut Rng, l: &Csr, opts: GqlOptions) -> Vec<Query> {
    let n = l.n;
    // a cheap 2-iteration bracket midpoint puts thresholds in the right
    // decade without an exact solve
    let rough = |u: &[f64]| run_scalar(l, u, opts, StopRule::Iters(2), false).bounds.mid();
    let mut qs = Vec::new();
    for i in 0..2 {
        let u = randvec(rng, n);
        let t = rough(&u) * (0.5 + 0.3 * i as f64);
        qs.push(Query::Threshold { u, t });
    }
    let (u, v) = (randvec(rng, n), randvec(rng, n));
    let t = 0.5 * rough(&v) - rough(&u) + if rng.bool(0.5) { 0.3 } else { -0.3 };
    qs.push(Query::Compare { u, v, t, p: 0.5 });
    qs.push(Query::Estimate { u: randvec(rng, n), stop: StopRule::GapRel(1e-8) });
    let arms = (0..3)
        .map(|_| QueryArm {
            u: randvec(rng, n),
            stop: StopRule::GapRel(1e-10),
            offset: 2.0 + rng.f64() * 3.0,
            scale: -1.0,
        })
        .collect();
    qs.push(Query::Argmax { arms, floor: None });
    qs
}

fn assert_bounds_eq(x: &Bounds, y: &Bounds, ctx: &str) {
    assert_eq!(x.iter, y.iter, "{ctx}: bounds iter");
    assert_eq!(x.gauss.to_bits(), y.gauss.to_bits(), "{ctx}: gauss bits");
    assert_eq!(x.radau_lower.to_bits(), y.radau_lower.to_bits(), "{ctx}: radau_lower bits");
    assert_eq!(x.radau_upper.to_bits(), y.radau_upper.to_bits(), "{ctx}: radau_upper bits");
    assert_eq!(x.lobatto.to_bits(), y.lobatto.to_bits(), "{ctx}: lobatto bits");
    assert_eq!(x.exact, y.exact, "{ctx}: exact flag");
}

/// Strict answer identity: decisions, outcomes, estimates (bitwise), and
/// per-lane iteration counts. Argmax sweep counts are deliberately
/// excluded — a session's sweep counter keeps running while one of its
/// queries is parked, so it measures scheduling, not numerics; the
/// per-arm eviction iterations (`pruned_at`) are the lane-level facts.
fn assert_same_answer(a: &Answer, b: &Answer, ctx: &str) {
    match (a, b) {
        (
            Answer::Estimate { bounds: x, iters: xi, .. },
            Answer::Estimate { bounds: y, iters: yi, .. },
        ) => {
            assert_eq!(xi, yi, "{ctx}: estimate iters");
            assert_bounds_eq(x, y, ctx);
        }
        (
            Answer::Threshold { decision: xd, stats: xs },
            Answer::Threshold { decision: yd, stats: ys },
        ) => {
            assert_eq!(xd, yd, "{ctx}: threshold decision");
            assert_eq!(xs.iters, ys.iters, "{ctx}: threshold iters");
            assert_eq!(xs.outcome, ys.outcome, "{ctx}: threshold outcome");
        }
        (
            Answer::Compare { decision: xd, stats: xs },
            Answer::Compare { decision: yd, stats: ys },
        ) => {
            assert_eq!(xd, yd, "{ctx}: compare decision");
            assert_eq!(xs.iters, ys.iters, "{ctx}: compare iters");
            assert_eq!(xs.outcome, ys.outcome, "{ctx}: compare outcome");
        }
        (
            Answer::Argmax { winner: xw, estimates: xe, stats: xs },
            Answer::Argmax { winner: yw, estimates: ye, stats: ys },
        ) => {
            assert_eq!(xw, yw, "{ctx}: argmax winner");
            assert_eq!(xe.len(), ye.len(), "{ctx}: estimate count");
            for (i, (ex, ey)) in xe.iter().zip(ye).enumerate() {
                assert_eq!(
                    ex.map(f64::to_bits),
                    ey.map(f64::to_bits),
                    "{ctx}: arm {i} estimate bits"
                );
            }
            assert_eq!(xs.pruned_at, ys.pruned_at, "{ctx}: per-arm eviction iters");
            assert_eq!(xs.decided_early, ys.decided_early, "{ctx}: early crowning");
        }
        _ => panic!("{ctx}: answer kinds differ"),
    }
}

/// The sequential reference: one `Session` per operator, same width, same
/// submission order, drained to completion on its own.
fn sequential_answers(
    ops: &[(Arc<Csr>, GqlOptions)],
    queries: &[Vec<Query>],
) -> Vec<Vec<Answer>> {
    ops.iter()
        .zip(queries)
        .map(|((l, opts), qs)| {
            let mut s = Session::new(&**l, *opts, PER_OP_LANES, RacePolicy::Prune);
            for q in qs {
                s.submit(q.clone());
            }
            s.run(&**l)
        })
        .collect()
}

/// Drive the same workload through one engine (round-robin submission —
/// per-operator order is what identity is stated over) and group the
/// answers back per operator.
fn engine_answers(
    ops: &[(Arc<Csr>, GqlOptions)],
    queries: &[Vec<Query>],
    ecfg: EngineConfig,
) -> Vec<Vec<Answer>> {
    let mut eng = Engine::new(ecfg).expect("test engine config is valid");
    let mut tickets: Vec<Vec<Ticket>> = vec![Vec::new(); ops.len()];
    let most = queries.iter().map(Vec::len).max().unwrap_or(0);
    for qi in 0..most {
        for (k, qs) in queries.iter().enumerate() {
            if let Some(q) = qs.get(qi) {
                let (l, opts) = &ops[k];
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
    }
    eng.drain();
    tickets
        .iter()
        .map(|ts| {
            ts.iter()
                .map(|&t| eng.answer(t).expect("engine drained").clone())
                .collect()
        })
        .collect()
}

fn check_identity(want: &[Vec<Answer>], got: &[Vec<Answer>], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: operator count");
    for (k, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.len(), g.len(), "{ctx}: op {k} query count");
        for (qi, (aw, ag)) in w.iter().zip(g).enumerate() {
            assert_same_answer(aw, ag, &format!("{ctx}: op {k} query {qi}"));
        }
    }
}

fn build_ops(rng: &mut Rng, count: usize, ridge: f64) -> Vec<(Arc<Csr>, GqlOptions)> {
    (0..count)
        .map(|_| {
            let n = 14 + rng.below(18);
            let (l, w) = random_sparse_spd(rng, n, 0.3, ridge);
            (Arc::new(l), GqlOptions::new(w.lo, w.hi))
        })
        .collect()
}

#[test]
fn engine_answers_are_bit_identical_to_sequential_sessions() {
    forall(8, 0xE9E1, |rng| {
        let ops = build_ops(rng, 2 + rng.below(3), 0.05);
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let want = sequential_answers(&ops, &queries);
        let ecfg = EngineConfig::default().with_width(PER_OP_LANES);
        check_identity(&want, &engine_answers(&ops, &queries, ecfg), "joint");
    });
}

#[test]
fn engine_identity_holds_under_full_reorth_on_ill_conditioned_kernels() {
    // tiny ridge ⇒ κ ~ 1e3–1e4: §5.4 territory, where plain Lanczos loses
    // bound validity — reorthogonalized lanes must stay bit-identical
    // through the joint scheduler too
    forall(4, 0xE9E2, |rng| {
        let ops: Vec<(Arc<Csr>, GqlOptions)> = build_ops(rng, 2, 1e-4)
            .into_iter()
            .map(|(l, opts)| (l, opts.with_reorth(Reorth::Full)))
            .collect();
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let want = sequential_answers(&ops, &queries);
        let ecfg = EngineConfig::default().with_width(PER_OP_LANES);
        check_identity(&want, &engine_answers(&ops, &queries, ecfg), "reorth");
    });
}

#[test]
fn streaming_submission_lands_mid_flight_bit_identically() {
    // half the queries enter up front, the rest are submitted after three
    // joint rounds; the reference drives each per-operator session with
    // the *same* two-phase schedule, so every state transition must match
    forall(6, 0xE9E3, |rng| {
        let ops = build_ops(rng, 2 + rng.below(2), 0.05);
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let split = 2usize; // thresholds first; compare/estimate/argmax stream in
        let presteps = 3usize;

        let want: Vec<Vec<Answer>> = ops
            .iter()
            .zip(&queries)
            .map(|((l, opts), qs)| {
                let mut s = Session::new(&**l, *opts, PER_OP_LANES, RacePolicy::Prune);
                for q in &qs[..split] {
                    s.submit(q.clone());
                }
                for _ in 0..presteps {
                    s.step(&**l);
                }
                for q in &qs[split..] {
                    s.submit(q.clone());
                }
                s.run(&**l)
            })
            .collect();

        let ecfg = EngineConfig::default().with_width(PER_OP_LANES);
        let mut eng = Engine::new(ecfg).expect("test engine config is valid");
        let mut tickets: Vec<Vec<Ticket>> = vec![Vec::new(); ops.len()];
        for (k, qs) in queries.iter().enumerate() {
            let (l, opts) = &ops[k];
            for q in &qs[..split] {
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
        for _ in 0..presteps {
            eng.step_round();
        }
        for (k, qs) in queries.iter().enumerate() {
            let (l, opts) = &ops[k];
            for q in &qs[split..] {
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
        eng.drain();
        let got: Vec<Vec<Answer>> = tickets
            .iter()
            .map(|ts| {
                ts.iter()
                    .map(|&t| eng.answer(t).expect("engine drained").clone())
                    .collect()
            })
            .collect();
        check_identity(&want, &got, "streaming");
    });
}

#[test]
fn suspend_resume_under_a_lane_budget_of_one_is_bit_identical() {
    // lanes = 1 forces the engine to park every query behind the
    // head-of-line one and resume them later; answers must not move a bit
    // relative to unconstrained sequential sessions
    forall(6, 0xE9E4, |rng| {
        let ops = build_ops(rng, 2, 0.05);
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let want = sequential_answers(&ops, &queries);
        let ecfg = EngineConfig::default().with_width(PER_OP_LANES).with_lanes(1);
        let mut eng = Engine::new(ecfg).expect("test engine config is valid");
        let mut tickets: Vec<Vec<Ticket>> = vec![Vec::new(); ops.len()];
        for (k, qs) in queries.iter().enumerate() {
            let (l, opts) = &ops[k];
            for q in qs {
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
        eng.drain();
        let st = eng.stats();
        assert!(st.parks > 0, "budget 1 must park queries");
        assert!(st.resumes > 0, "parked queries must resume");
        // the head-of-line query runs whole, so the admitted demand never
        // exceeds the largest single query (the 3-arm argmax)
        assert!(st.peak_live_lanes <= 3, "budget 1 admitted {} lanes", st.peak_live_lanes);
        let got: Vec<Vec<Answer>> = tickets
            .iter()
            .map(|ts| {
                ts.iter()
                    .map(|&t| eng.answer(t).expect("engine drained").clone())
                    .collect()
            })
            .collect();
        check_identity(&want, &got, "budget-1");
    });
}

#[test]
fn parallel_workers_preserve_bit_identity_on_mixed_workloads() {
    // the acceptance bar asks for ≥ 2 parallel workers; sweep 2 and 4
    forall(4, 0xE9E5, |rng| {
        let ops = build_ops(rng, 3 + rng.below(2), 0.05);
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let want = sequential_answers(&ops, &queries);
        for workers in [2usize, 4] {
            let ecfg = EngineConfig::default()
                .with_width(PER_OP_LANES)
                .with_workers(workers);
            check_identity(
                &want,
                &engine_answers(&ops, &queries, ecfg),
                &format!("{workers} workers"),
            );
        }
    });
}

#[test]
fn sweep_modes_match_sequential_across_worker_counts_and_skewed_sizes() {
    // ISSUE 8 tentpole identity: the index-claiming work-stealing sweep
    // (plain and profiled) must answer bit-identically to sequential
    // per-operator sessions at any worker count — including the skewed
    // shape stealing exists to balance, one operator dwarfing the rest —
    // and so must the static baseline it replaced as the default
    forall(3, 0xE9EB, |rng| {
        let mut ops = build_ops(rng, 3, 0.05);
        // skew: one operator several times the panel dimension of the
        // others, so its session's steps dominate every round
        let n = 90 + rng.below(30);
        let (l, w) = random_sparse_spd(rng, n, 0.1, 0.05);
        ops.push((Arc::new(l), GqlOptions::new(w.lo, w.hi)));
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let want = sequential_answers(&ops, &queries);
        let workers = 2 + rng.below(7); // random 2..=8 per case
        for (mode, tag) in [(SweepMode::Stealing, "stealing"), (SweepMode::Static, "static")] {
            for profiled in [false, true] {
                let ecfg = EngineConfig::default()
                    .with_width(PER_OP_LANES)
                    .with_workers(workers)
                    .with_sweep_mode(mode)
                    .with_profile(profiled);
                check_identity(
                    &want,
                    &engine_answers(&ops, &queries, ecfg),
                    &format!("{tag} w={workers} profiled={profiled}"),
                );
            }
        }
    });
}

#[test]
fn work_stealing_handles_mid_flight_submissions_bit_identically() {
    // streaming submission under the stealing fan-out: queries landing
    // between rounds must not perturb a single step of the sessions
    // already in flight, at any worker count, profiled or not
    forall(4, 0xE9EC, |rng| {
        let ops = build_ops(rng, 3, 0.05);
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let split = 2usize;
        let presteps = 3usize;
        let want: Vec<Vec<Answer>> = ops
            .iter()
            .zip(&queries)
            .map(|((l, opts), qs)| {
                let mut s = Session::new(&**l, *opts, PER_OP_LANES, RacePolicy::Prune);
                for q in &qs[..split] {
                    s.submit(q.clone());
                }
                for _ in 0..presteps {
                    s.step(&**l);
                }
                for q in &qs[split..] {
                    s.submit(q.clone());
                }
                s.run(&**l)
            })
            .collect();

        let workers = 2 + rng.below(7);
        let profiled = rng.bool(0.5);
        let ecfg = EngineConfig::default()
            .with_width(PER_OP_LANES)
            .with_workers(workers)
            .with_sweep_mode(SweepMode::Stealing)
            .with_profile(profiled);
        let mut eng = Engine::new(ecfg).expect("test engine config is valid");
        let mut tickets: Vec<Vec<Ticket>> = vec![Vec::new(); ops.len()];
        for (k, qs) in queries.iter().enumerate() {
            let (l, opts) = &ops[k];
            for q in &qs[..split] {
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
        for _ in 0..presteps {
            eng.step_round();
        }
        for (k, qs) in queries.iter().enumerate() {
            let (l, opts) = &ops[k];
            for q in &qs[split..] {
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
        eng.drain();
        let got: Vec<Vec<Answer>> = tickets
            .iter()
            .map(|ts| {
                ts.iter()
                    .map(|&t| eng.answer(t).expect("engine drained").clone())
                    .collect()
            })
            .collect();
        check_identity(&want, &got, &format!("mid-flight w={workers} profiled={profiled}"));
    });
}

#[test]
fn skewed_profiled_round_reports_sane_worker_accounting() {
    // the profiler's utilization numbers must stay internally consistent
    // under the stealing sweep (busy ≤ capacity, fracs in [0,1]) and the
    // steal counter must actually fire on a skewed multi-operator round
    let mut rng = Rng::new(0xE9ED);
    let mut ops = build_ops(&mut rng, 3, 0.05);
    let (l, w) = random_sparse_spd(&mut rng, 110, 0.1, 0.05);
    ops.push((Arc::new(l), GqlOptions::new(w.lo, w.hi)));
    let queries: Vec<Vec<Query>> = ops
        .iter()
        .map(|(l, opts)| mixed_queries(&mut rng, l, *opts))
        .collect();
    let ecfg = EngineConfig::default()
        .with_width(PER_OP_LANES)
        .with_workers(4)
        .with_profile(true);
    let mut eng = Engine::new(ecfg).expect("test engine config is valid");
    for (k, qs) in queries.iter().enumerate() {
        let (l, opts) = &ops[k];
        for q in qs {
            eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone());
        }
    }
    eng.drain();
    let p = eng.profile().expect("profiled engine collects a profile").clone();
    assert!(p.busy_ns <= p.capacity_ns, "busy cannot exceed capacity");
    assert!((0.0..=1.0).contains(&p.busy_frac()));
    assert!((0.0..=1.0).contains(&p.idle_frac()));
    let st = eng.stats();
    assert!(st.pool_reuse >= 1, "multi-round stealing run reuses the pool");
    let reg = MetricsRegistry::new();
    eng.export_into(&reg);
    let snap = reg.snapshot();
    assert!(
        matches!(snap.get("engine.profile.steal_count"), Some(MetricValue::Counter(_))),
        "steal counter exported"
    );
    assert!(
        matches!(snap.get("engine.profile.pool_reuse"), Some(MetricValue::Counter(c)) if *c >= 1),
        "pool reuse exported"
    );
}

#[test]
fn streaming_after_an_operator_went_idle_reuses_or_respins_sessions() {
    // an engine kept alive across bursts: drain one burst, let the TTL
    // evict the idle session, submit a second burst under the same key —
    // answers must still match fresh sequential sessions
    let mut rng = Rng::new(0xE9E6);
    let ops = build_ops(&mut rng, 2, 0.05);
    let ecfg = EngineConfig::default().with_width(PER_OP_LANES).with_ttl_rounds(1);
    let mut eng = Engine::new(ecfg).expect("test engine config is valid");
    for burst in 0..3 {
        // thresholds/compares/estimates only: a session reused across
        // bursts keeps its adaptive prune-margin state, so argmax queries
        // are excluded here — the reference would start from a fresh
        // margin (argmax identity across scheduling is covered by the
        // single-burst tests above)
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| {
                let mut qs = mixed_queries(&mut rng, l, *opts);
                qs.truncate(4); // drop the argmax (last entry)
                qs
            })
            .collect();
        let want = sequential_answers(&ops, &queries);
        let mut tickets: Vec<Vec<Ticket>> = vec![Vec::new(); ops.len()];
        for (k, qs) in queries.iter().enumerate() {
            let (l, opts) = &ops[k];
            for q in qs {
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
        eng.drain();
        let got: Vec<Vec<Answer>> = tickets
            .iter()
            .map(|ts| {
                ts.iter()
                    .map(|&t| eng.answer(t).expect("engine drained").clone())
                    .collect()
            })
            .collect();
        check_identity(&want, &got, &format!("burst {burst}"));
    }
    assert!(eng.stats().sessions_spun >= 2, "sessions spin up lazily per key");
}

// ---------------------------------------------------------------------------
// Resident-engine invariants (ISSUE 7): store eviction, ticket
// compaction, shed admission.
// ---------------------------------------------------------------------------

#[test]
fn lru_eviction_and_readmission_preserve_bit_identity() {
    // a resident engine under a 1-byte store budget: every drained burst
    // is followed by idle rounds that TTL-evict the sessions and LRU-drop
    // their released operators; the next burst re-admits the operators
    // cold and must still answer bit-identically to fresh sequential
    // sessions
    let mut rng = Rng::new(0xE9E7);
    let ops = build_ops(&mut rng, 2, 0.05);
    let ecfg = EngineConfig::default()
        .with_width(PER_OP_LANES)
        .with_ttl_rounds(1)
        .with_store_bytes(1);
    let mut eng = Engine::new(ecfg).expect("test engine config is valid");
    for burst in 0..2 {
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(&mut rng, l, *opts))
            .collect();
        let want = sequential_answers(&ops, &queries);
        let mut tickets: Vec<Vec<Ticket>> = vec![Vec::new(); ops.len()];
        for (k, qs) in queries.iter().enumerate() {
            let (l, opts) = &ops[k];
            for q in qs {
                tickets[k].push(eng.submit(k as OpKey, Arc::clone(l), *opts, q.clone()));
            }
        }
        eng.drain();
        let got: Vec<Vec<Answer>> = tickets
            .iter()
            .map(|ts| {
                ts.iter()
                    .map(|&t| eng.take_answer(t).expect("engine drained"))
                    .collect()
            })
            .collect();
        check_identity(&want, &got, &format!("evict burst {burst}"));
        // idle rounds past the TTL: sessions evict, pins release, and the
        // 1-byte budget drops the operators from the store entirely
        for _ in 0..3 {
            eng.step_round();
        }
        assert_eq!(eng.store().resident(), 0, "burst {burst}: budget evicts released ops");
        assert!(!eng.store().contains(0) && !eng.store().contains(1));
    }
    assert!(eng.store().evicted() >= 4, "both ops evicted after each burst");
    assert!(eng.store().inserted() >= 4, "re-admission re-inserts evicted ops");
    assert!(eng.stats().sessions_spun >= 4, "each burst re-spins evicted sessions");
}

#[test]
fn compacted_tickets_go_stale_instead_of_aliasing() {
    let mut rng = Rng::new(0xE9E8);
    let ops = build_ops(&mut rng, 1, 0.05);
    let (l, opts) = &ops[0];
    let mut eng = Engine::new(EngineConfig::default().with_width(PER_OP_LANES))
        .expect("test engine config is valid");
    let u = randvec(&mut rng, l.n);
    let t0 = eng.submit(
        0,
        Arc::clone(l),
        *opts,
        Query::Estimate { u, stop: StopRule::GapRel(1e-8) },
    );
    // unresolved tickets refuse without compacting
    assert!(matches!(eng.take_answer(t0), Err(TicketError::Unresolved)));
    eng.drain();
    assert!(matches!(eng.take_answer(t0), Ok(Answer::Estimate { .. })));
    // the slot compacted: the taken ticket (and any retained copy) is
    // permanently stale, for reads and takes alike
    assert!(matches!(eng.take_answer(t0), Err(TicketError::Stale)));
    assert!(eng.answer(t0).is_none(), "stale tickets read as unanswered");
    assert!(!eng.is_resolved(t0));
    // a later submission reuses the compacted slab slot under a bumped
    // generation — the stale ticket must keep erroring, never alias the
    // query that now lives in its old index
    let u2 = randvec(&mut rng, l.n);
    let t1 = eng.submit(
        0,
        Arc::clone(l),
        *opts,
        Query::Estimate { u: u2, stop: StopRule::GapRel(1e-8) },
    );
    eng.drain();
    assert!(matches!(eng.take_answer(t0), Err(TicketError::Stale)));
    assert!(matches!(eng.take_answer(t1), Ok(Answer::Estimate { .. })));
    assert!(eng.stats().compactions >= 2, "every take_answer compacts its slot");
}

#[test]
fn shed_answers_carry_a_valid_four_bound_bracket() {
    let mut rng = Rng::new(0xE9E9);
    let ops = build_ops(&mut rng, 1, 0.05);
    let (l, opts) = &ops[0];
    let n = l.n;
    let ecfg = EngineConfig::default().with_width(PER_OP_LANES).with_queue_cap(2);
    let mut eng = Engine::new(ecfg).expect("test engine config is valid");
    // two slow estimates fill the cap; one round gives each a bracket
    let q0 = Query::Estimate { u: randvec(&mut rng, n), stop: StopRule::GapRel(1e-12) };
    let t0 = eng
        .try_submit(0, Arc::clone(l), *opts, q0, Some(1_000))
        .expect("below cap admits");
    let q1 = Query::Estimate { u: randvec(&mut rng, n), stop: StopRule::GapRel(1e-12) };
    let t1 = eng
        .try_submit(0, Arc::clone(l), *opts, q1, Some(1))
        .expect("below cap admits");
    eng.step_round();
    // at cap: admission sheds the least-urgent in-flight estimate (the
    // loose-deadline t0), which resolves NOW to its current bracket —
    // the anytime property of the Gauss/Radau/Lobatto sweep
    let q2 = Query::Estimate { u: randvec(&mut rng, n), stop: StopRule::GapRel(1e-12) };
    let t2 = eng
        .try_submit(0, Arc::clone(l), *opts, q2, Some(1))
        .expect("shed makes room");
    assert_eq!(eng.stats().shed, 1, "exactly one victim shed");
    assert!(eng.is_resolved(t0), "the shed victim resolves immediately");
    match eng.take_answer(t0).expect("shed answer is harvestable") {
        Answer::Estimate { bounds, iters, .. } => {
            assert!(iters >= 1, "shed after a sweep: bracket is real, not a placeholder");
            assert!(bounds.lower().is_finite() && bounds.upper().is_finite());
            assert!(bounds.lower() <= bounds.upper(), "shed bracket still encloses");
            assert!(!bounds.exact, "a mid-flight bracket is not an exact solve");
        }
        _ => panic!("shed victim was an estimate"),
    }
    eng.drain();
    assert!(matches!(eng.take_answer(t1), Ok(Answer::Estimate { .. })));
    assert!(matches!(eng.take_answer(t2), Ok(Answer::Estimate { .. })));

    // refill the cap with decision queries: nothing sheddable carries a
    // bracket to answer with, so admission refuses instead of lying
    for _ in 0..2 {
        let u = randvec(&mut rng, n);
        eng.try_submit(0, Arc::clone(l), *opts, Query::Threshold { u, t: 0.0 }, Some(1))
            .expect("below cap admits");
    }
    let u = randvec(&mut rng, n);
    let refused = eng.try_submit(0, Arc::clone(l), *opts, Query::Threshold { u, t: 0.0 }, Some(1));
    assert!(matches!(refused, Err(SubmitError::Saturated)));
    eng.drain();
}

#[test]
fn flight_recorder_on_or_off_is_bit_identical() {
    // the recorder hooks admission, the lane-budget pass, and harvest —
    // never `Session::step` or the panel sweep — so answers must not move
    // a bit when it is disabled, and both must match the sequential
    // reference; exercised under a lane budget and parallel workers so
    // the park/resume and fan-out paths emit events too
    forall(5, 0xE9EE, |rng| {
        let ops = build_ops(rng, 2 + rng.below(2), 0.05);
        let queries: Vec<Vec<Query>> = ops
            .iter()
            .map(|(l, opts)| mixed_queries(rng, l, *opts))
            .collect();
        let want = sequential_answers(&ops, &queries);
        let base = EngineConfig::default().with_width(PER_OP_LANES);
        for ecfg in [
            base,
            base.with_lanes(1),
            base.with_workers(2 + rng.below(3)),
        ] {
            let on = engine_answers(&ops, &queries, ecfg.with_flight(true));
            check_identity(&want, &on, "flight on vs sequential");
            let off = engine_answers(&ops, &queries, ecfg.with_flight(false));
            check_identity(&on, &off, "flight on vs off");
        }
    });
}

#[test]
fn export_publishes_the_store_and_admission_schema() {
    // satellite of the PR-6 telemetry layer: the resident-engine series
    // (`engine.store.*`, `engine.admission.*`) must appear in a snapshot
    // with stable names and kinds — the CI soak step validates the same
    // schema out of the serve binary's JSON
    let mut rng = Rng::new(0xE9EA);
    let ops = build_ops(&mut rng, 2, 0.05);
    let mut eng = Engine::new(EngineConfig::default().with_width(PER_OP_LANES))
        .expect("test engine config is valid");
    for (k, (l, opts)) in ops.iter().enumerate() {
        let u = randvec(&mut rng, l.n);
        let q = Query::Estimate { u, stop: StopRule::GapRel(1e-6) };
        eng.submit(k as OpKey, Arc::clone(l), *opts, q);
    }
    eng.drain();
    let reg = MetricsRegistry::new();
    eng.export_into(&reg);
    let snap = reg.snapshot();
    for name in [
        "engine.store.inserted",
        "engine.store.evicted",
        "engine.admission.admitted",
        "engine.admission.parked",
        "engine.admission.shed",
        "engine.admission.compactions",
    ] {
        assert!(
            matches!(snap.get(name), Some(MetricValue::Counter(_))),
            "snapshot missing counter {name}"
        );
    }
    for name in ["engine.store.resident", "engine.store.pinned", "engine.store.resident_bytes"] {
        assert!(
            matches!(snap.get(name), Some(MetricValue::Gauge(_))),
            "snapshot missing gauge {name}"
        );
    }
    match snap.get("engine.admission.admitted") {
        Some(MetricValue::Counter(c)) => assert_eq!(*c, 2, "one admit per submission"),
        _ => unreachable!(),
    }
    match snap.get("engine.store.resident") {
        Some(MetricValue::Gauge(g)) => assert!(*g >= 1.0, "ops stay resident after drain"),
        _ => unreachable!(),
    }
}
