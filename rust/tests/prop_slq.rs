//! Stochastic Lanczos quadrature invariants (ISSUE 9), stated against a
//! dense oracle:
//!
//! * for every supported spectral function, the exact spectral sum lies
//!   inside the reported combined interval (deterministic quadrature
//!   envelope ⊕ Monte-Carlo t-interval; checked with a 4× guard band so
//!   a 95% confidence statement gates at an effective ≫99.99% level);
//! * a pinned [`SlqConfig`] seed makes the whole report bit-identical
//!   across worker counts and both [`SweepMode`]s — probes are seeded at
//!   submission, so scheduling cannot leak into the answer;
//! * a `Trace` query shed mid-flight under backpressure resolves to its
//!   current combined interval (the anytime property), never to garbage.

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::linalg::{sym_eigenvalues, Cholesky};
use gauss_bif::quadrature::block::StopRule;
use gauss_bif::quadrature::engine::{Engine, EngineConfig, SubmitError, SweepMode};
use gauss_bif::quadrature::query::{Answer, Query, Session};
use gauss_bif::quadrature::race::RacePolicy;
use gauss_bif::quadrature::stochastic::{SlqConfig, SpectralFn, StochasticReport};
use gauss_bif::quadrature::GqlOptions;
use gauss_bif::sparse::{Csr, SymOp};
use gauss_bif::util::prop::forall;
use gauss_bif::util::rng::Rng;
use std::sync::Arc;

/// `|exact − mid| ≤ 4·half-width`: containment with enough guard that a
/// pinned-seed run cannot flake on the 95% t-interval.
fn guarded_containment(r: &StochasticReport, exact: f64, what: &str) {
    let half = r.combined.width() / 2.0;
    let slack = 1e-9 * (1.0 + exact.abs());
    assert!(
        (exact - r.combined.mid()).abs() <= 4.0 * half + slack,
        "{what}: exact {exact} outside guarded [{}, {}]",
        r.combined.lo,
        r.combined.hi
    );
    assert!(
        r.combined.lo <= r.envelope.lo + slack && r.envelope.hi <= r.combined.hi + slack,
        "{what}: envelope [{}, {}] escapes combined [{}, {}]",
        r.envelope.lo,
        r.envelope.hi,
        r.combined.lo,
        r.combined.hi
    );
    assert!(
        r.combined.contains(r.estimate),
        "{what}: estimate {} outside its own interval",
        r.estimate
    );
}

#[test]
fn dense_oracle_lies_in_the_reported_interval_for_every_spectral_fn() {
    forall(4, 0x51AB1, |rng| {
        let n = 16 + rng.below(12);
        let (a, w) = random_sparse_spd(rng, n, 0.1, 0.5);
        let dense = a.to_dense();
        let ev = sym_eigenvalues(&dense);
        let ch = Cholesky::factor(&dense).expect("generator output is PD");
        let exact_tr: f64 = (0..n)
            .map(|i| {
                let mut e = vec![0.0; n];
                e[i] = 1.0;
                ch.bif(&e)
            })
            .sum();
        let opts = GqlOptions::new(w.lo, w.hi);
        let cfg = SlqConfig::new(12, rng.next_u64(), 2e-2);
        let cases: [(Query, f64, &str); 4] = [
            (Query::Trace { f: SpectralFn::Inverse, cfg }, exact_tr, "tr(A^-1)"),
            (Query::LogDet { cfg }, ch.logdet(), "logdet"),
            (
                Query::Trace { f: SpectralFn::Exp, cfg },
                ev.iter().map(|l| l.exp()).sum(),
                "tr(exp(A))",
            ),
            (
                Query::Trace { f: SpectralFn::Power(0.5), cfg },
                ev.iter().map(|l| l.sqrt()).sum(),
                "tr(A^0.5)",
            ),
        ];
        for (q, exact, what) in cases {
            let mut s = Session::new(&a, opts, cfg.probes, RacePolicy::Prune);
            let qid = s.submit(q);
            let answers = s.run(&a);
            let r = answers[qid].stochastic().expect("stochastic answer");
            guarded_containment(r, exact, what);
            assert_eq!(r.probes_issued, cfg.probes);
            assert!(r.probes_contributing == cfg.probes, "{what}: a probe vanished");
        }
    });
}

fn drain_one(
    a: &Arc<Csr>,
    opts: GqlOptions,
    q: &Query,
    workers: usize,
    mode: SweepMode,
) -> StochasticReport {
    let cfg = EngineConfig::default().with_workers(workers).with_sweep_mode(mode);
    let mut eng = Engine::new(cfg).expect("valid engine config");
    let t = eng.submit(1, Arc::clone(a) as Arc<dyn SymOp>, opts, q.clone());
    eng.drain();
    eng.answer(t)
        .and_then(Answer::stochastic)
        .expect("stochastic queries answer stochastically")
        .clone()
}

#[test]
fn pinned_seed_reports_are_bit_identical_across_scheduling() {
    forall(3, 0x51AB2, |rng| {
        let n = 24 + rng.below(16);
        let (a, w) = random_sparse_spd(rng, n, 0.08, 0.5);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let cfg = SlqConfig::new(8, rng.next_u64(), 5e-2);
        for q in [Query::Trace { f: SpectralFn::Inverse, cfg }, Query::LogDet { cfg }] {
            let want = drain_one(&a, opts, &q, 1, SweepMode::Stealing);
            for workers in [1usize, 2, 4] {
                for mode in [SweepMode::Stealing, SweepMode::Static] {
                    let got = drain_one(&a, opts, &q, workers, mode);
                    assert_eq!(
                        want.estimate.to_bits(),
                        got.estimate.to_bits(),
                        "estimate drifted at {workers} workers ({mode:?})"
                    );
                    assert_eq!(want.combined.lo.to_bits(), got.combined.lo.to_bits());
                    assert_eq!(want.combined.hi.to_bits(), got.combined.hi.to_bits());
                    assert_eq!(want.iters, got.iters);
                    assert_eq!(want.rounds, got.rounds);
                    assert_eq!(want.probes_retired_early, got.probes_retired_early);
                }
            }
        }
    });
}

#[test]
fn shed_trace_queries_resolve_to_their_current_interval() {
    let mut rng = Rng::new(0x51AB3);
    let n = 32;
    let (a, w) = random_sparse_spd(&mut rng, n, 0.08, 0.5);
    let a = Arc::new(a);
    let opts = GqlOptions::new(w.lo, w.hi);
    // a tolerance no finite panel meets: the query can only finish by
    // exhaustion or by being shed
    let cfg = SlqConfig::new(6, 0x51AB4, 1e-15);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let estimate = Query::Estimate { u, stop: StopRule::GapRel(1e-6) };

    let mut eng =
        Engine::new(EngineConfig::default().with_queue_cap(1)).expect("valid engine config");
    let t = eng.submit(
        1,
        Arc::clone(&a) as Arc<dyn SymOp>,
        opts,
        Query::Trace { f: SpectralFn::Inverse, cfg },
    );
    // before any sweep there is no bracket to answer with — admission
    // must refuse rather than shed garbage
    let err = eng
        .try_submit(2, Arc::clone(&a) as Arc<dyn SymOp>, opts, estimate.clone(), None)
        .expect_err("nothing sheddable before the first sweep");
    assert_eq!(err, SubmitError::Saturated);

    for _ in 0..3 {
        assert!(eng.step_round(), "trace query still in flight");
    }
    let t2 = eng
        .try_submit(2, Arc::clone(&a) as Arc<dyn SymOp>, opts, estimate, None)
        .expect("an in-flight bracketed trace query is sheddable");
    let r = eng
        .answer(t)
        .and_then(Answer::stochastic)
        .expect("shed trace query resolves immediately")
        .clone();
    assert!(r.combined.lo.is_finite() && r.combined.hi.is_finite());
    assert!(r.combined.lo <= r.estimate && r.estimate <= r.combined.hi);
    assert!(r.probes_contributing >= 1, "shed answer must carry at least one probe");
    assert!(!r.tol_met, "1e-15 cannot have been met");
    assert_eq!(eng.stats().shed, 1);

    eng.drain();
    assert!(
        matches!(eng.answer(t2), Some(Answer::Estimate { .. })),
        "the admitted estimate must still complete"
    );
}
